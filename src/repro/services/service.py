"""Persistent service tasks: N replicas + a routed request stream.

The paper's IMPECCABLE inference runs as long-lived *services* rather than
batch jobs, and RHAPSODY (arXiv:2512.20795) names service tasks as the task
modality that makes hybrid AI-HPC campaigns scale: provision once, then
amortize the launch cost over a stream of requests. A :class:`Service` owns
``replicas`` tasks with ``kind="service"`` that run the persistent lifecycle
added to the task state machine::

    NEW -> SCHEDULING -> QUEUED -> LAUNCHING -> PROVISIONING -> READY
                                                  -> SERVING -> DRAINING -> STOPPED

Replica tasks flow through the normal agent dispatch pipeline (routing,
placement, resource allocation); the hosting executor advances them to
PROVISIONING/READY and calls back into the service, which then routes
requests across ready replicas with a pluggable load balancer.

Engine duality, same as everywhere else in the substrate:

* **sim** — each replica is a single server with service time
  ``noisy(1/rate)`` per request (calibrated per-replica service-rate model);
  request completions are discrete events on the engine clock.
* **real** — each replica occupies one executor worker thread for its whole
  lifetime and blocks on a per-replica ``queue.Queue``; ``handler(payload)``
  executes in that persistent worker (no per-request dispatch through the
  task pipeline).

Fault model (the RP characterization paper, arXiv:2103.00091, measures
failure-recovery overhead as a first-order term at leadership scale):

* **request requeue** — in-flight and queued requests of a FAILED/CANCELED
  replica are re-dispatched to survivors through the balancer; a request
  fails only after ``max_retries`` requeues. Retry counts live in the
  columnar request log.
* **replica restart** — with a :class:`RestartPolicy`, a dead replica is
  replaced by resubmitting a fresh ``TaskDescription`` (``restarted_from``
  records the lineage) through the normal dispatch pipeline after a backoff,
  so ``n_replicas`` is a target the service converges back to, not a
  snapshot of the initial provisioning.
* **autoscaling** — with a :class:`ScalePolicy`, the ``least-outstanding``
  queue-depth signal provisions or drains replicas against the live
  allocation. Evaluation is purely event-driven (request submission,
  completion, readiness) so the sim engine sees it as discrete events and
  the real engine needs no poller thread.

All service entry points serialize on ``engine.lock``, so the same Service
code drives both engines and composes with campaigns (replica STOPPED is a
terminal task state; an elastic stage holds until ``Service.stopped``).
"""
from __future__ import annotations

import queue as _thread_queue
from array import array
from collections import deque
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional

from repro.core.task import Task, TaskDescription, TaskState, new_uid

# trace-name registry (entity = service name): restart / autoscale events
# recorded by the fault model, resolved by the observability layer instead
# of hardcoded strings
TRACE_NAMES: Dict[str, str] = {
    "restart": "service:restart",          # replica replacement scheduled
    "scale_up": "service:scale_up",        # autoscale provision
    "scale_down": "service:scale_down",    # autoscale drain
}

# sentinel handed to a real replica's request queue to end its serve loop
SVC_STOP = object()

# request status codes for the columnar ok-flags
_PENDING, _OK, _FAILED = 0, 1, 2


@dataclass(frozen=True)
class RestartPolicy:
    """Replica restart on failure: a FAILED/CANCELED replica is replaced by
    resubmitting a fresh ``TaskDescription`` through the agent's dispatch
    pipeline (``restarted_from`` records the lineage), bringing the live
    count back toward the ``n_replicas`` target. ``backoff`` delays the
    resubmission (engine-seconds) and grows by ``factor`` per restart
    already spent, bounding churn under a crash loop."""

    max_restarts: int = 4          # total replacement budget for the service
    backoff: float = 1.0
    factor: float = 2.0

    def delay(self, n_prior: int) -> float:
        return self.backoff * (self.factor ** n_prior)


@dataclass(frozen=True)
class ScalePolicy:
    """Elastic replica autoscaling from the ``least-outstanding`` queue
    signal: when the mean backlog per routable replica exceeds
    ``up_threshold`` requests, one replica is provisioned (until
    ``max_replicas``); when it falls below ``down_threshold``, one idle
    replica is drained (down to ``min_replicas``). Evaluated as discrete
    events on request submission / completion / readiness — never by
    polling — with ``cooldown`` engine-seconds between actions."""

    min_replicas: int = 1
    max_replicas: int = 8
    up_threshold: float = 4.0
    down_threshold: float = 0.25
    cooldown: float = 5.0


class RoundRobinBalancer:
    """Cycle through ready replicas in order. The cursor is clamped to the
    rotation length on every pick and compensated (``note_removed``) when
    the Service removes a replica ahead of it, so shrink/grow under replica
    death or autoscaling continues the rotation instead of skewing load
    onto whichever replica happened to fill the removed slot."""

    def __init__(self):
        self._i = 0

    def pick(self, replicas: List["Replica"]) -> "Replica":
        if self._i >= len(replicas):
            self._i = 0
        r = replicas[self._i]
        self._i += 1
        return r

    def note_removed(self, index: int):
        if index < self._i:
            self._i -= 1


class LeastOutstandingBalancer:
    """Route to the ready replica with the fewest in-flight requests."""

    def pick(self, replicas: List["Replica"]) -> "Replica":
        return min(replicas, key=lambda r: r.outstanding)


_BALANCERS = {"round-robin": RoundRobinBalancer,
              "least-outstanding": LeastOutstandingBalancer}


def make_balancer(spec) -> Any:
    """Resolve a balancer name ("round-robin" | "least-outstanding") or pass
    an instance through (anything with ``pick(replicas)``)."""
    if isinstance(spec, str):
        try:
            return _BALANCERS[spec]()
        except KeyError:
            raise KeyError(f"unknown balancer {spec!r} "
                           f"(available: {sorted(_BALANCERS)})") from None
    return spec


class Replica:
    """Per-replica runtime state: the hosting Task, its in-flight count, and
    its request queue (deque of rids in sim, thread Queue in real)."""

    __slots__ = ("task", "outstanding", "queue", "busy", "served",
                 "stop_sent", "current", "event", "draining")

    def __init__(self, task: Task, real: bool):
        self.task = task
        self.outstanding = 0           # dispatched, not yet completed
        self.queue = _thread_queue.Queue() if real else deque()
        self.busy = False              # sim: a request is in service
        self.served = 0
        self.stop_sent = False         # real: drain sentinel enqueued
        self.current = -1              # sim: rid in service (requeue on death)
        self.event = None              # sim: its scheduled completion event
        self.draining = False          # autoscale: leaving the rotation


class Service:
    """N persistent replicas + request routing; see module docstring.

    Parameters
    ----------
    agent : the pilot agent hosting the replicas (engine + backends).
    handler : real-mode request handler, called as ``handler(payload)`` in
        the replica's persistent worker; ``None`` echoes the payload.
    replicas : target number of service tasks (autoscaling moves it).
    cores/gpus/nodes : per-replica resource footprint (normal routing rules).
    startup : sim-mode provisioning time (s) per replica.
    rate : sim-mode per-replica request service rate (req/s); a request may
        override with an explicit ``duration``.
    balancer : "round-robin" | "least-outstanding" | instance with ``pick``.
    max_retries : requeues a request survives before failing (replica-death
        requeue; handler exceptions are application errors, never retried).
    restart : optional :class:`RestartPolicy` — replace dead replicas.
    scale : optional :class:`ScalePolicy` — elastic replica count.
    """

    def __init__(self, agent, handler: Optional[Callable] = None,
                 replicas: int = 2, cores: int = 1, gpus: int = 0,
                 nodes: int = 0, startup: float = 0.0, rate: float = 0.0,
                 rate_sigma: float = 0.15, balancer="round-robin",
                 backend: Optional[str] = None, name: str = "",
                 workflow: str = "", max_retries: int = 2,
                 restart: Optional[RestartPolicy] = None,
                 scale: Optional[ScalePolicy] = None,
                 submitter=None):
        assert replicas >= 1
        self.agent = agent
        # replica placement authority: restart replacements and scale-up
        # provisions resubmit through this (a repro.sched.CampaignScheduler
        # routes/charges them against its placement views; default: the
        # agent's dispatch pipeline directly)
        self.submitter = submitter if submitter is not None else agent
        self.engine = agent.engine
        self.handler = handler
        self.n_replicas = replicas          # the *target* live-replica count
        self.startup = startup
        self.rate = rate
        self.rate_sigma = rate_sigma
        self.balancer = make_balancer(balancer)
        self.name = name or new_uid("service")
        self.max_retries = max_retries
        self.restart = restart
        self.scale = scale
        self.error: Optional[str] = None
        self._real = self.engine.mode == "real"
        self._descriptions: Optional[List[TaskDescription]] = None
        self._all_descs: List[TaskDescription] = []   # originals + replacements
        self._desc_kw = dict(cores=cores, gpus=gpus, nodes=nodes,
                             backend=backend, workflow=workflow)

        self._replicas: Dict[str, Replica] = {}      # uid -> Replica
        self._ready: List[Replica] = []              # live READY/SERVING
        self._n_marked = 0                           # draining/stop_sent in _ready
        self._n_submitted = 0                        # descriptions created
        self._n_terminal = 0                         # replica tasks finished
        self._buffer: deque = deque()                # rids awaiting readiness
        self._flushed = False
        self._stopping = False
        self._finalized = False
        self._ready_cbs: List[Callable[[], None]] = []
        self._stopped_cbs: List[Callable[[], None]] = []

        # fault/elasticity bookkeeping
        self.restarts = 0                            # replacements scheduled
        self._pending_restarts = 0                   # scheduled, not submitted
        self._scale_t = array("d")                   # scale-event times
        self._scale_delta = array("b")               # +1 provision / -1 drain
        self._last_scale = float("-inf")

        # columnar per-request log (events.py style): parallel arrays indexed
        # by rid; starts/ends are assigned out of order, so placeholders are
        # appended at submission and overwritten in place
        self._submit_ts = array("d")
        self._start_ts = array("d")
        self._end_ts = array("d")
        self._ok = bytearray()
        self._retries = bytearray()                  # requeues per rid
        self._payloads: List[Any] = []
        self._durations: List[Optional[float]] = []
        self.results: List[Any] = []
        self._n_done = 0
        # completion journal: rids in completion order — a streaming reader
        # (observability watch / ServiceLatencyRule) tails this in O(new)
        # via completed_since() instead of rescanning the whole log
        self._done_journal = array("q")

        agent.add_done_callback(self._replica_terminal)

    # ------------------------------------------------------------- replicas
    def descriptions(self) -> List[TaskDescription]:
        """The initial replica TaskDescriptions (memoized) — submit these
        through the agent/TaskManager, or return them from a campaign stage.
        Restart replacements and scale-ups are resubmitted internally and do
        not appear here (see ``all_descriptions``)."""
        if self._descriptions is None:
            self._descriptions = [self._new_desc()
                                  for _ in range(self.n_replicas)]
        return self._descriptions

    def all_descriptions(self) -> List[TaskDescription]:
        """Every replica description ever created: the initial set plus
        restart replacements and autoscale provisions, in creation order."""
        return list(self._all_descs)

    def _new_desc(self, restarted_from: Optional[str] = None
                  ) -> TaskDescription:
        self._n_submitted += 1
        d = TaskDescription(kind="service", service=self,
                            uid=new_uid(f"{self.name}.replica"),
                            restarted_from=restarted_from,
                            **self._desc_kw)
        self._all_descs.append(d)
        return d

    def submit(self) -> List[Task]:
        """Convenience: submit the replica tasks through the placement
        authority (the campaign scheduler when one was configured)."""
        return self.submitter.submit(self.descriptions())

    # executor callbacks ------------------------------------------------
    def _attach_replica(self, task: Task) -> Replica:
        """Idempotently create the Replica record for a provisioning task
        (real executors need the request queue before READY)."""
        r = self._replicas.get(task.uid)
        if r is None:
            r = self._replicas[task.uid] = Replica(task, self._real)
        return r

    def _replica_ready(self, task: Task):
        """Hosting executor reports the replica READY (under engine.lock)."""
        r = self._attach_replica(task)
        self._ready.append(r)
        self._maybe_flush()
        if self._flushed:
            self._rebalance()          # late joiner steals queued work
        if self._stopping:
            self._maybe_stop_all()
        if self.all_ready:
            for cb in self._ready_cbs:
                cb()
            self._ready_cbs.clear()
        self._maybe_scale()

    def _replica_terminal(self, task: Task):
        """Agent done-callback: drop dead replicas from the rotation,
        recover their requests, and (policy permitting) schedule a
        replacement. The back-reference check keeps this O(1) on the
        agent's completion hot path (the callback sees every task the
        agent finishes)."""
        if task.description.service is not self:
            return
        self._n_terminal += 1
        r = self._replicas.get(task.uid)
        if r is not None:
            self._remove_from_ready(r)
        if (task.state in (TaskState.FAILED, TaskState.CANCELED)
                and self.error is None):
            self.error = f"replica {task.uid}: {task.state.value}"
        if task.state is not TaskState.STOPPED:
            self._maybe_restart(task)
            if r is not None:
                self._recover_replica_requests(r, task)
        self._maybe_flush()                 # fewer live replicas to wait for
        if self._stopping:
            # a replica death can leave idle survivors undrained (their
            # earlier stop check was skipped while requests sat buffered)
            self._maybe_stop_all()
        self._check_stopped()

    def _remove_from_ready(self, r: Replica):
        try:
            idx = self._ready.index(r)
        except ValueError:
            return
        if r.draining or r.stop_sent:
            # already left the rotation (cursor compensated at mark time)
            self._n_marked = max(0, self._n_marked - 1)
        else:
            self._note_leaving_rotation(r)
        self._ready.pop(idx)

    def _note_leaving_rotation(self, r: Replica):
        """Tell the balancer a replica is leaving the *rotation* — in
        rotation coordinates, since the cursor indexes the filtered list,
        not ``_ready``. Called before the mark/removal takes effect."""
        note = getattr(self.balancer, "note_removed", None)
        if note is None:
            return
        rot_idx = 0
        for other in self._ready:
            if other is r:
                note(rot_idx)
                return
            if not (other.draining or other.stop_sent):
                rot_idx += 1

    def _rotation(self) -> List[Replica]:
        """Replicas eligible for new work: ready and not on their way out
        (a drain sentinel is FIFO-ordered — work behind it is never served)."""
        if self._n_marked == 0:
            return self._ready
        return [r for r in self._ready if not (r.draining or r.stop_sent)]

    @property
    def n_live(self) -> int:
        """Replica tasks submitted and not yet terminal (any state)."""
        return self._n_submitted - self._n_terminal

    # ---------------------------------------------------------------- faults
    def _maybe_restart(self, task: Task) -> bool:
        """Schedule a replacement for a dead replica (under engine.lock)."""
        rp = self.restart
        if rp is None:
            return False
        if self._stopping and self._n_done >= len(self._submit_ts):
            return False                   # nothing left to serve
        # draining replicas are leaving the rotation — they must not count
        # as target coverage, or a death during a drain goes unreplaced
        if (self.n_live - self._n_marked + self._pending_restarts
                >= self.n_replicas):
            return False                   # target already covered
        if self.restarts >= rp.max_restarts:
            return False
        n_prior = self.restarts
        self.restarts += 1
        self._pending_restarts += 1
        self.engine.profiler.record(self.engine.now(), self.name,
                                    TRACE_NAMES["restart"],
                                    {"of": task.uid, "n": self.restarts})
        self.engine.schedule(max(rp.delay(n_prior), 1e-6),
                             self._submit_replacement, task.uid)
        return True

    def _submit_replacement(self, failed_uid: str):
        with self.engine.lock:
            self._pending_restarts -= 1
            if self._stopping and self._n_done >= len(self._submit_ts):
                # the stream drained while the backoff ran: abandon
                self._check_stopped()
                return
            desc = self._new_desc(restarted_from=failed_uid)
            self.submitter.resubmit([desc], origin=failed_uid)

    def _recover_replica_requests(self, r: Replica, task: Task):
        """Requests still queued or in flight on a FAILED/CANCELED replica
        are re-dispatched to survivors through the balancer; a rid that has
        burned its ``max_retries`` requeues fails with the replica's
        epitaph instead."""
        reason = f"replica {task.uid} {task.state.value}"
        rids: List[int] = []
        if self._real:
            sentinel = False
            try:
                while True:
                    item = r.queue.get_nowait()
                    if item is SVC_STOP:
                        sentinel = True    # keep the serve loop's wakeup
                        continue
                    rids.append(item[0])
            except _thread_queue.Empty:
                pass
            if sentinel:
                r.queue.put(SVC_STOP)
        else:
            rids.extend(r.queue)
            r.queue.clear()
            if r.busy:
                # the in-flight request: cancel its completion event and
                # retry it first (it has waited longest)
                if r.event is not None:
                    r.event.cancel()
                r.event = None
                r.busy = False
                if r.current >= 0:
                    rids.insert(0, r.current)
                r.current = -1
        for rid in rids:
            r.outstanding -= 1
            self._requeue_or_fail(rid, reason)

    def _requeue_inflight(self, r: Replica, rid: int, reason: str):
        """A real replica popped ``rid`` but died before starting its
        handler (called from the worker thread, under engine.lock)."""
        r.outstanding -= 1
        self._requeue_or_fail(rid, reason)

    def _requeue_or_fail(self, rid: int, reason: str):
        if self._end_ts[rid] >= 0.0:
            return                         # already terminal
        if self._retries[rid] >= self.max_retries:
            self._fail_rid(rid, f"{reason} (after {self._retries[rid]} "
                                f"retries)")
            return
        self._retries[rid] += 1
        self._start_ts[rid] = -1.0         # back in queue: start stamp resets
        live = self._rotation()
        if live:
            self._dispatch(rid, live)
        elif self.n_live > 0 or self._pending_restarts > 0:
            self._buffer.append(rid)       # a replacement is on its way
        else:
            self._fail_rid(rid, f"{reason} (no replicas left)")

    def kill_replica(self, uid: Optional[str] = None,
                     reason: str = "chaos kill") -> Optional[str]:
        """Fault injection: fail one live replica through its hosting
        executor (the normal on_failure path), which triggers request
        requeue and — with a RestartPolicy — a replacement. Picks the first
        ready replica when ``uid`` is None (falling back to one still
        provisioning). Returns the uid killed, or None."""
        with self.engine.lock:
            task: Optional[Task] = None
            if uid is not None:
                t = self.agent.tasks.get(uid)
                # only this service's replicas are valid targets — a stale
                # or foreign uid must not kill an unrelated agent task
                task = (t if t is not None and not t.done
                        and t.description.service is self else None)
            else:
                for r in self._ready:
                    if not r.task.done:
                        task = r.task
                        break
                if task is None:           # chaos strikes before readiness
                    for d in self._all_descs:
                        t = self.agent.tasks.get(d.uid)
                        if t is not None and not t.done and t.state in (
                                TaskState.PROVISIONING, TaskState.READY,
                                TaskState.SERVING):
                            task = t
                            break
            if task is None:
                return None
            ex = self.agent.backends.get(task.backend)
            if ex is not None:
                ex.fail_task(task, reason)
            return task.uid if task.done else None

    # ----------------------------------------------------------- autoscaling
    def _maybe_scale(self):
        """Evaluate the ScalePolicy against the live queue signal (under
        engine.lock; called from request/completion/readiness events)."""
        sp = self.scale
        if sp is None or not self._flushed:
            return
        now = self.engine.now()
        if now - self._last_scale < sp.cooldown:
            return
        live = self._rotation()
        if not live:
            return
        backlog = len(self._submit_ts) - self._n_done   # in flight + buffered
        per_replica = backlog / len(live)
        target = self.n_live + self._pending_restarts
        # scale-up stays armed while stopping — a declared stop still owes
        # the submitted stream saturation; scale-down is redundant there
        # (the stop protocol drains idle replicas itself)
        if per_replica > sp.up_threshold and target < sp.max_replicas:
            self._last_scale = now
            self.n_replicas += 1
            self._scale_t.append(now)
            self._scale_delta.append(1)
            desc = self._new_desc()
            self.engine.profiler.record(now, self.name,
                                        TRACE_NAMES["scale_up"],
                                        {"target": self.n_replicas})
            self.submitter.resubmit([desc], origin="scale-up")
        elif (not self._stopping and per_replica < sp.down_threshold
                and len(live) > 1 and target > max(1, sp.min_replicas)):
            idle = [r for r in live if r.outstanding == 0]
            if idle:
                self._last_scale = now
                self.n_replicas = max(1, self.n_replicas - 1)
                self._scale_t.append(now)
                self._scale_delta.append(-1)
                self.engine.profiler.record(now, self.name,
                                            TRACE_NAMES["scale_down"],
                                            {"target": self.n_replicas})
                self._drain_replica(idle[-1])

    def _drain_replica(self, r: Replica):
        """Take one replica out of the rotation and stop it (scale-down)."""
        task = r.task
        if task.done or r.draining or r.stop_sent:
            return
        self._note_leaving_rotation(r)
        r.draining = True
        self._n_marked += 1
        if task.state in (TaskState.READY, TaskState.SERVING):
            task.advance(TaskState.DRAINING, self.engine.now(),
                         self.engine.profiler)
        if self._real:
            r.stop_sent = True
            r.queue.put(SVC_STOP)
        elif not r.busy and not r.queue and r.outstanding == 0:
            ex = self.agent.backends.get(task.backend)
            if ex is not None:
                ex.stop_service(task)
        # else: sim replica still loaded — _sim_done finalizes the drain
        # once its queue empties (finalizing now would strand queued rids:
        # STOPPED replicas skip request recovery)

    def scale_log(self) -> Dict[str, Any]:
        """Columnar autoscale trace: event times and +1/-1 deltas."""
        return {"t": self._scale_t, "delta": self._scale_delta}

    def replica_seconds(self) -> float:
        """Aggregate replica availability: READY -> terminal per replica
        task, summed over every replica ever provisioned. Exact under
        elasticity, where a `replicas x window` product has no meaning
        (the count varies over the window)."""
        total = 0.0
        now = self.engine.now()
        tasks = self.agent.tasks
        for d in self._all_descs:
            t = tasks.get(d.uid)
            if t is None:
                continue
            ts = t.timestamps
            r0 = ts.get("READY")
            if r0 is None:
                continue                   # died before serving anything
            end = ts.get("STOPPED")
            if end is None:
                end = ts.get("FAILED", ts.get("CANCELED", now))
            total += max(0.0, end - r0)
        return total

    # ---------------------------------------------------------- rebalancing
    def _queue_len(self, r: Replica) -> int:
        return r.queue.qsize() if self._real else len(r.queue)

    def _steal_queued(self, r: Replica) -> List[int]:
        """Take r's queued (not in-flight) rids back (under engine.lock)."""
        rids: List[int] = []
        if self._real:
            try:
                while True:
                    item = r.queue.get_nowait()
                    if item is SVC_STOP:   # defensive: keep the wakeup
                        r.queue.put(SVC_STOP)
                        break
                    rids.append(item[0])
            except _thread_queue.Empty:
                pass
        else:
            rids.extend(r.queue)
            r.queue.clear()
        r.outstanding -= len(rids)
        return rids

    def _rebalance(self):
        """Even out queued (not in-flight) requests across the rotation.
        Replicas own their queues, so without this a scale-up or restart
        joiner would idle until new arrivals while loaded survivors grind —
        work stealing is what turns provisioning into recovered throughput.
        No retry is charged: stealing is routing, not failure."""
        live = self._rotation()
        if len(live) < 2:
            return
        sizes = [self._queue_len(r) for r in live]
        if max(sizes) - min(sizes) <= 1:
            return                     # already balanced: skip the churn
        stolen: List[int] = []
        for r in live:
            stolen.extend(self._steal_queued(r))
        if not stolen:
            return
        stolen.sort()                  # oldest requests re-dispatch first
        for rid in stolen:
            self._dispatch(rid, live)

    # ------------------------------------------------------------- requests
    def request(self, payload: Any = None,
                duration: Optional[float] = None) -> int:
        """Enqueue one request; returns its rid. Buffered until replicas are
        ready. ``duration`` overrides the sim service time for this request."""
        with self.engine.lock:
            if self._stopping or self._finalized:
                # _finalized covers death-without-stop(): every replica is
                # gone and none is coming, so the rid could only strand
                raise RuntimeError(f"{self.name}: stopped — no new requests")
            rid = len(self._submit_ts)
            self._submit_ts.append(self.engine.now())
            self._start_ts.append(-1.0)
            self._end_ts.append(-1.0)
            self._ok.append(_PENDING)
            self._retries.append(0)
            self._payloads.append(payload)
            self._durations.append(duration)
            self.results.append(None)
            live = self._rotation() if self._flushed else None
            if live:
                self._dispatch(rid, live)
            else:
                self._buffer.append(rid)
            self._maybe_scale()
        return rid

    def submit_requests(self, payloads) -> List[int]:
        return [self.request(p) for p in payloads]

    def _maybe_flush(self):
        """Release buffered requests once every still-live replica is ready
        (keeps the balancer's spread deterministic for buffered bursts);
        replicas lost before readiness shrink the expectation instead of
        stranding the buffer."""
        if not self._flushed:
            if self._ready and len(self._ready) >= self.n_live:
                self._flushed = True
        if self._flushed and self._buffer:
            live = self._rotation()
            if live:
                while self._buffer:
                    self._dispatch(self._buffer.popleft(), live)

    def _dispatch(self, rid: int, live: Optional[List[Replica]] = None):
        r = self.balancer.pick(live if live is not None else self._rotation())
        r.outstanding += 1
        task = r.task
        if task.state is TaskState.READY:
            task.advance(TaskState.SERVING, self.engine.now(),
                         self.engine.profiler)
        if self._real:
            r.queue.put((rid, self._payloads[rid]))
        else:
            r.queue.append(rid)
            if not r.busy:
                self._sim_start(r)

    # sim request execution --------------------------------------------
    def _sim_start(self, r: Replica):
        rid = r.queue.popleft()
        r.busy = True
        r.current = rid
        self._start_ts[rid] = self.engine.now()
        dur = self._durations[rid]
        if dur is None:
            dur = (self.engine.noisy(1.0 / self.rate, self.rate_sigma)
                   if self.rate > 0 else 1e-6)
        r.event = self.engine.schedule(max(dur, 1e-6), self._sim_done, r, rid)

    def _sim_done(self, r: Replica, rid: int):
        r.busy = False
        r.event = None
        r.current = -1
        if r.task.done:
            # the replica died mid-request through a path that bypassed the
            # terminal callback's recovery (e.g. a direct executor cancel):
            # its allocation is gone, so hand the request to a survivor
            r.outstanding -= 1
            self._requeue_or_fail(rid,
                                  f"replica {r.task.uid} {r.task.state.value}")
            return
        self._end_ts[rid] = self.engine.now()
        self._ok[rid] = _OK
        self._n_done += 1
        self._done_journal.append(rid)
        r.outstanding -= 1
        r.served += 1
        self._maybe_scale()
        if r.queue:
            self._sim_start(r)
        elif r.draining and r.outstanding == 0:
            # deferred scale-down drain: the queue just emptied
            ex = self.agent.backends.get(r.task.backend)
            if ex is not None:
                ex.stop_service(r.task)
        elif self._stopping:
            self._maybe_stop_replica(r)

    def _fail_rid(self, rid: int, reason: str):
        if self._end_ts[rid] >= 0.0:
            return
        self._end_ts[rid] = self.engine.now()
        self._ok[rid] = _FAILED
        self.results[rid] = reason
        self._n_done += 1
        self._done_journal.append(rid)

    # real request execution (called by the replica's worker thread) ----
    def _request_start(self, rid: int):
        self._start_ts[rid] = self.engine.now()

    def _request_complete(self, r: Replica, rid: int, result: Any, ok: bool):
        self._end_ts[rid] = self.engine.now()
        self._ok[rid] = _OK if ok else _FAILED
        self._n_done += 1
        self._done_journal.append(rid)
        self.results[rid] = result
        r.outstanding -= 1
        r.served += 1
        self._maybe_scale()

    # ------------------------------------------------------------------ stop
    def stop(self):
        """Graceful stop: serve everything already submitted (including
        buffered requests), then drain and stop every replica. Replicas not
        yet READY finalize as soon as they get there; pending restarts are
        abandoned. Idempotent."""
        with self.engine.lock:
            if self._stopping:
                return
            self._stopping = True
            self._maybe_stop_all()
            self._check_stopped()

    def _flush_or_fail_buffer(self):
        """Stop protocol: the normal flush waits for *every* live replica to
        be ready, but while stopping that can deadlock — a replica stuck
        QUEUED behind a full pool only launches once the ready ones drain,
        and they will not drain while the buffer waits on it. So flush once
        every *launched* live replica (PROVISIONING or beyond, i.e. holding
        resources) is ready: provisioning replicas reach readiness in
        finite time (preserving the balanced spread), queued ones are not
        waited for. With no live or incoming replica left, buffered
        requests fail instead of stranding as PENDING forever."""
        if not self._buffer:
            return
        live = self._rotation()
        if live and self._stop_flush_ready():
            self._flushed = True
            while self._buffer:
                self._dispatch(self._buffer.popleft(), live)
        elif (self._n_submitted > 0 and self.n_live == 0
                and self._pending_restarts == 0):
            # every replica ever created is terminal and no replacement is
            # coming: the buffered requests can never be delivered
            while self._buffer:
                self._fail_rid(self._buffer.popleft(),
                               "service stopped before any replica was ready")
        # else: replicas are still progressing (or not yet submitted —
        # campaign stages declare stop() before submitting descriptions);
        # readiness flushes for us

    def _stop_flush_ready(self) -> bool:
        """May the stop protocol release the buffer now? Yes at full
        readiness, or once no live replica is *progressing* toward READY
        (SCHEDULING / LAUNCHING / PROVISIONING all have a scheduled event
        driving them there; QUEUED does not — it waits on resources the
        ready replicas may themselves be holding, which is the deadlock the
        early flush breaks)."""
        if len(self._ready) >= self.n_live:
            return True
        if self._pending_restarts:
            return False
        tasks = self.agent.tasks
        for d in self._all_descs:
            t = tasks.get(d.uid)
            if (t is not None and not t.done and t.state in
                    (TaskState.SCHEDULING, TaskState.LAUNCHING,
                     TaskState.PROVISIONING)):
                return False
        return True

    def _maybe_stop_all(self):
        self._flush_or_fail_buffer()
        for r in list(self._ready):
            self._maybe_stop_replica(r)

    def _maybe_stop_replica(self, r: Replica):
        task = r.task
        if task.done or self._buffer:
            # undelivered buffered requests: the flush must spread them
            # across replicas before any replica drains
            return
        if self._real:
            # DRAINING now; the serve loop works off what is already queued
            # (sentinel is FIFO-ordered behind it) and then stops itself
            if not r.stop_sent:
                self._note_leaving_rotation(r)
                r.stop_sent = True
                self._n_marked += 1
                if task.state in (TaskState.READY, TaskState.SERVING):
                    task.advance(TaskState.DRAINING, self.engine.now(),
                                 self.engine.profiler)
                r.queue.put(SVC_STOP)
        elif not r.busy and not r.queue and r.outstanding == 0:
            # sim: idle — but a loaded sibling may still hold queued work
            # this replica could take; draining it now would burn capacity
            # (and invite the scale-up/drain churn the rebalance avoids)
            self._rebalance()
            if r.busy or r.queue or r.outstanding:
                return                 # stole work: drain when truly done
            # drained — finalize through the hosting executor so the
            # allocation is released and on_complete reaches the agent
            if task.state in (TaskState.READY, TaskState.SERVING):
                task.advance(TaskState.DRAINING, self.engine.now(),
                             self.engine.profiler)
            ex = self.agent.backends.get(task.backend)
            if ex is not None:
                ex.stop_service(task)

    def _check_stopped(self):
        """Fire the shutdown edge exactly once: when the last replica goes
        terminal with nothing pending, fail any requests still buffered
        (they would otherwise strand as PENDING and skew ``outstanding``)
        and notify on_stopped listeners (campaign stage release)."""
        if self._finalized or not self.stopped:
            return
        self._finalized = True
        while self._buffer:
            self._fail_rid(self._buffer.popleft(),
                           "service stopped with request undelivered")
        for cb in self._stopped_cbs:
            cb()
        self._stopped_cbs.clear()

    # ------------------------------------------------------------------ state
    @property
    def n_ready(self) -> int:
        return len(self._ready)

    @property
    def all_ready(self) -> bool:
        return (self._flushed and self._ready
                and len(self._ready) == self.n_live)

    @property
    def n_requests(self) -> int:
        return len(self._submit_ts)

    @property
    def n_completed(self) -> int:
        return self._n_done

    @property
    def outstanding(self) -> int:
        return len(self._submit_ts) - self._n_done - len(self._buffer)

    @property
    def stopped(self) -> bool:
        """All replica tasks (including restart replacements and scale-ups)
        reached a terminal state, with no replacement pending."""
        return (self._n_submitted > 0
                and self._n_terminal >= self._n_submitted
                and self._pending_restarts == 0)

    def on_ready(self, cb: Callable[[], None]):
        """Run ``cb`` once every replica is READY (immediately if they are)."""
        with self.engine.lock:
            if self.all_ready:
                cb()
            else:
                self._ready_cbs.append(cb)

    def on_stopped(self, cb: Callable[[], None]):
        """Run ``cb`` once the service has fully shut down — every replica
        terminal, no restart pending (immediately if already stopped)."""
        with self.engine.lock:
            if self._finalized:
                cb()
            else:
                self._stopped_cbs.append(cb)

    # ------------------------------------------------------------------ waits
    def wait_ready(self, timeout: Optional[float] = None) -> bool:
        """Block until every replica is READY (real engine; on the sim engine
        this drains the event heap first — prefer ``on_ready`` there)."""
        return self.engine.drain(lambda: self.all_ready or self.stopped,
                                 timeout=timeout)

    def wait_requests(self, timeout: Optional[float] = None) -> bool:
        return self.engine.drain(
            lambda: self._n_done >= len(self._submit_ts) or self.stopped,
            timeout=timeout)

    def wait_stopped(self, timeout: Optional[float] = None) -> bool:
        return self.engine.drain(lambda: self.stopped, timeout=timeout)

    # -------------------------------------------------------------- analytics
    def request_log(self) -> Dict[str, Any]:
        """Columnar request trace for analytics: parallel arrays of submit /
        start / end timestamps, status codes (0 pending, 1 ok, 2 failed),
        and per-request requeue counts."""
        return {"submit": self._submit_ts, "start": self._start_ts,
                "end": self._end_ts, "ok": self._ok,
                "retries": self._retries}

    def completed_since(self, pos: int):
        """``(rids, new_pos)``: request ids completed (ok or failed) since
        journal position ``pos``, in completion order — the O(new) cursor
        streaming consumers poll (e.g. the observability layer's rolling
        service-p99 health rule)."""
        hi = len(self._done_journal)
        if pos >= hi:
            return [], hi
        return list(self._done_journal[pos:hi]), hi

    def served_per_replica(self) -> Dict[str, int]:
        return {uid: r.served for uid, r in self._replicas.items()}

    def __repr__(self):
        return (f"<Service {self.name} target={self.n_replicas} "
                f"live={self.n_live} ready={self.n_ready} "
                f"requests={self.n_requests} done={self._n_done} "
                f"restarts={self.restarts}>")
