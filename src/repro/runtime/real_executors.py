"""Real-mode executor backends: the same BaseExecutor surface the simulator's
backend models implement, but payloads actually execute on this host.

Backends mirror the simulation split:
  * ``dragon``   — a worker-thread pool for in-process Python *function* tasks
    (Dragon's native mode: no process spawn per task, shared interpreter
    state / device buffers). Also hosts persistent *service* replicas: a
    replica occupies one worker thread for its lifetime and serves requests
    from its queue (see ``repro.services``).
  * ``flux``     — co-scheduled *executable* tasks; each partition maps to a
    jax submesh (core/partition.py) and runs its tasks serially
    (co-scheduling: one tightly-coupled job owns the partition at a time).
    Task callables that declare a ``mesh`` keyword receive their partition's
    submesh.
  * ``popen``    — external executables launched as subprocesses
    (``TaskDescription.executable`` + ``arguments``); stdout becomes
    ``task.result``.
  * ``funcpool`` — Raptor/Dragon-style master/worker function execution:
    persistent OS worker processes pull pickled callables off a shared queue
    (no per-call process spawn, true multi-core parallelism); a collector
    thread commits completions back into the task pipeline.

All task state transitions are committed under ``engine.lock`` and followed
by ``engine.notify()``, so the agent's single-threaded lifecycle logic
(retries, speculation, campaign stage release) runs unchanged on top.
"""
from __future__ import annotations

import inspect
import multiprocessing as mp
import os
import queue
import subprocess
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Dict, List, Optional

from repro.core.executors.base import BaseExecutor
from repro.core.partition import carve_submeshes
from repro.core.task import Task, TaskState
from repro.runtime.registry import register_executor
from repro.services.service import SVC_STOP


def _accepts_kw(fn, name: str) -> bool:
    if fn is None:
        return False
    try:
        return name in inspect.signature(fn).parameters
    except (TypeError, ValueError):
        return False


class RealExecutorBase(BaseExecutor):
    """Thread-pool executor skeleton: queueing, cancellation, and locked
    state commits; subclasses provide ``_payload``."""

    def __init__(self, engine, name: str, workers: int,
                 thread_prefix: str = "worker"):
        super().__init__(name)
        self.engine = engine
        self.workers = workers
        self._pool = ThreadPoolExecutor(max_workers=max(1, workers),
                                        thread_name_prefix=thread_prefix)
        self._futures: Dict[str, Future] = {}
        # submitted-but-not-yet-started tasks (parallel to _futures) and
        # tasks whose payload is executing — the chaos/evacuation surface
        self._pending_tasks: Dict[str, Task] = {}
        self._running_tasks: Dict[str, Task] = {}
        self._active = 0
        # request queues of hosted service replicas (uid -> Queue), so
        # shutdown can unblock their serve loops with a stop sentinel
        self._service_queues: Dict[str, "queue.Queue"] = {}

    # ------------------------------------------------------------- lifecycle
    def start(self) -> float:
        self.alive = True
        return 0.0

    def submit(self, task: Task):
        task.backend = self.name
        try:
            self._pending_tasks[task.uid] = task
            self._futures[task.uid] = self._pool.submit(self._run, task)
        except RuntimeError as e:       # pool shut down (session closed)
            self._pending_tasks.pop(task.uid, None)
            eng = self.engine
            task.error = f"{self.name}: {e}"
            task.advance(TaskState.FAILED, eng.now(), eng.profiler)
            self.stats["failed"] += 1
            if self.on_failure:
                self.on_failure(task, task.error)
            eng.notify()

    def _run(self, task: Task):
        if task.description.kind == "service":
            return self._run_service(task)
        eng = self.engine
        with eng.lock:
            self._futures.pop(task.uid, None)
            self._pending_tasks.pop(task.uid, None)
            if task.done:                         # canceled while queued
                return
            self._active += 1
            task.attempt += 1
            attempt = task.attempt
            self._running_tasks[task.uid] = task
            task.advance(TaskState.LAUNCHING, eng.now(), eng.profiler)
            task.advance(TaskState.RUNNING, eng.now(), eng.profiler)
            self.stats["launched"] += 1
            wt = task.description.walltime
            if wt > 0.0:
                eng.schedule(wt, self._enforce_walltime, task, attempt)
        try:
            result = self._payload(task)
        except Exception as e:                                # noqa: BLE001
            err = f"{type(e).__name__}: {e}"
            with eng.lock:
                self._active -= 1
                # the attempt guard discards a stale thread's commit: the
                # task may have been failed by chaos/walltime, requeued,
                # and relaunched as a newer attempt while this payload ran
                if not task.done and task.attempt == attempt:
                    self._running_tasks.pop(task.uid, None)
                    task.error = err
                    task.advance(TaskState.FAILED, eng.now(), eng.profiler)
                    self.stats["failed"] += 1
                    if self.on_failure:
                        self.on_failure(task, err)
            eng.notify()
            return
        with eng.lock:
            self._active -= 1
            if not task.done and task.attempt == attempt:
                self._running_tasks.pop(task.uid, None)
                task.result = result
                task.advance(TaskState.DONE, eng.now(), eng.profiler)
                self.stats["completed"] += 1
                if self.on_complete:
                    self.on_complete(task)
        eng.notify()

    def _enforce_walltime(self, task: Task, attempt: int):
        """Walltime timer fired: if that attempt is still running, fail the
        task with reason. The payload thread cannot be killed — its eventual
        commit is discarded by the done/attempt guards (cooperative
        enforcement; the worker slot frees when the payload returns)."""
        eng = self.engine
        with eng.lock:
            if (task.done or task.attempt != attempt
                    or task.uid not in self._running_tasks):
                return
            eng.profiler.record(eng.now(), task.uid, "task:walltime",
                                {"limit": task.description.walltime,
                                 "attempt": attempt})
            self.fail_task(task, "walltime exceeded")

    def _payload(self, task: Task):
        raise NotImplementedError

    def _resume_kwargs(self, task: Task, kwargs: dict) -> dict:
        """Checkpoint-restart contract: a task with ``checkpoint_dir`` gets
        a CheckpointManager injected as ``checkpoint`` and the step to
        resume from as ``resume_from`` (explicit ``description.resume_from``
        wins, else the latest checkpoint on disk; None on a cold start) —
        each only if the callable declares the keyword, mirroring the
        ``mesh`` injection. Import is deferred: the checkpoint module pulls
        in jax at import time."""
        d = task.description
        if not d.checkpoint_dir or d.fn is None:
            return kwargs
        wants_mgr = _accepts_kw(d.fn, "checkpoint")
        wants_step = _accepts_kw(d.fn, "resume_from")
        if not (wants_mgr or wants_step):
            return kwargs
        from repro.checkpoint.checkpoint import CheckpointManager
        mgr = CheckpointManager(d.checkpoint_dir, async_save=False)
        step = (d.resume_from if d.resume_from is not None
                else mgr.latest_step())
        if wants_mgr:
            kwargs["checkpoint"] = mgr
        if wants_step:
            kwargs["resume_from"] = step
        if step is not None:
            eng = self.engine
            with eng.lock:
                eng.profiler.record(eng.now(), task.uid, "task:resume",
                                    {"progress": step, "cores": d.cores})
        return kwargs

    # --------------------------------------------------------------- services
    def _run_service(self, task: Task):
        """Host a persistent service replica: this worker thread IS the
        replica for its whole lifetime — provision, signal readiness, then
        block on the replica's request queue executing ``handler(payload)``
        per request until the owning Service enqueues the stop sentinel
        (drain semantics: the sentinel is FIFO-ordered behind the queue)."""
        eng = self.engine
        svc = task.description.service
        with eng.lock:
            self._futures.pop(task.uid, None)
            self._pending_tasks.pop(task.uid, None)
            if task.done or svc is None:          # canceled while queued
                return
            self._active += 1
            self._running_tasks[task.uid] = task
            task.advance(TaskState.LAUNCHING, eng.now(), eng.profiler)
            task.advance(TaskState.PROVISIONING, eng.now(), eng.profiler)
            self.stats["launched"] += 1
            replica = svc._attach_replica(task)
            self._service_queues[task.uid] = replica.queue
        eng.notify()
        handler = svc.handler
        with eng.lock:
            if not task.done:
                task.advance(TaskState.READY, eng.now(), eng.profiler)
                svc._replica_ready(task)
        eng.notify()
        while True:
            item = replica.queue.get()
            if item is SVC_STOP:
                break
            rid, payload = item
            with eng.lock:
                if task.done:
                    # replica killed/canceled between dispatch and pickup:
                    # hand the request back for redispatch to survivors
                    # (the fault model requeues before failing)
                    svc._requeue_inflight(replica, rid,
                                          f"replica {task.uid} "
                                          f"{task.state.value}")
                    break
                svc._request_start(rid)
            try:
                result = handler(payload) if handler is not None else payload
                ok = True
            except Exception as e:                                # noqa: BLE001
                result = f"{type(e).__name__}: {e}"
                ok = False
            with eng.lock:
                svc._request_complete(replica, rid, result, ok)
            eng.notify()
        with eng.lock:
            self._active -= 1
            self._service_queues.pop(task.uid, None)
            self._running_tasks.pop(task.uid, None)
            if not task.done:
                if task.state in (TaskState.PROVISIONING, TaskState.READY,
                                  TaskState.SERVING):
                    task.advance(TaskState.DRAINING, eng.now(), eng.profiler)
                task.advance(TaskState.STOPPED, eng.now(), eng.profiler)
                self.stats["completed"] += 1
                if self.on_complete:
                    self.on_complete(task)
        eng.notify()

    def stop_service(self, task: Task):
        """Unblock a hosted replica's serve loop (the Service normally does
        this itself via the replica queue; this is the generic surface)."""
        q = self._service_queues.get(task.uid)
        if q is not None:
            q.put(SVC_STOP)

    def fail_task(self, task: Task, reason: str = "executor kill") -> bool:
        """Fault injection: fail one hosted task (batch payload or service
        replica) through the normal on_failure path. For a replica, the
        owning Service recovers its queued requests inside the on_failure
        callback (same lock acquisition), and the stop sentinel — enqueued
        after recovery so it is not swallowed by the queue drain — unblocks
        the serve loop."""
        eng = self.engine
        with eng.lock:
            if task.done:
                return False
            fut = self._futures.pop(task.uid, None)
            if fut is not None:
                fut.cancel()
            self._pending_tasks.pop(task.uid, None)
            self._running_tasks.pop(task.uid, None)
            task.error = f"{self.name}: {reason}"
            task.advance(TaskState.FAILED, eng.now(), eng.profiler)
            self.stats["failed"] += 1
            if self.on_failure:
                self.on_failure(task, task.error)
            q = self._service_queues.get(task.uid)
            if q is not None:              # unblock the replica's loop
                q.put(SVC_STOP)
        eng.notify()
        return True

    def running_tasks(self) -> List[Task]:
        with self.engine.lock:
            return list(self._running_tasks.values())

    def fail_node(self, node: int, reason: str = "node failure"
                  ) -> Optional[List[Task]]:
        """Real backends have no node pools (a worker thread stands in for
        a node): emulate a node loss by shrinking the worker pool by one
        and failing one running payload, if any. Node ids are nominal
        here; returns None once the pool is down to its last worker."""
        eng = self.engine
        with eng.lock:
            if self.workers <= 1:
                return None
            self.workers -= 1
            victims = list(self._running_tasks.values())[:1]
        for t in victims:
            self.fail_task(t, reason)
        return victims

    def evacuate(self) -> List[Task]:
        """Pilot death: cancel queued payloads (returned for requeue to
        surviving pilots) and fail running ones through on_failure. A
        future that refuses to cancel is already entering ``_run``; failing
        its task now means the worker thread sees a terminal state under
        the lock and returns without launching. Payload threads already
        executing cannot be killed — their eventual commits are discarded
        by the done/attempt guards."""
        eng = self.engine
        with eng.lock:
            orphans: List[Task] = []
            doomed: List[Task] = []
            for uid, task in list(self._pending_tasks.items()):
                fut = self._futures.get(uid)
                if fut is None or fut.cancel():
                    self._futures.pop(uid, None)
                    self._pending_tasks.pop(uid, None)
                    if not task.done:
                        orphans.append(task)
                else:
                    doomed.append(task)
            doomed.extend(self._running_tasks.values())
        for t in doomed:
            self.fail_task(t, "executor failure")
        self.alive = False
        self._pool.shutdown(wait=False, cancel_futures=True)
        eng.notify()
        return orphans

    # --------------------------------------------------------------- control
    def cancel(self, task: Task):
        eng = self.engine
        with eng.lock:
            fut = self._futures.pop(task.uid, None)
            if fut is not None:
                fut.cancel()
            self._pending_tasks.pop(task.uid, None)
            self._running_tasks.pop(task.uid, None)
            if not task.done:
                # a still-running payload sees the terminal state at commit
                # time and discards its result
                task.advance(TaskState.CANCELED, eng.now(), eng.profiler)
            q = self._service_queues.get(task.uid)
            if q is not None:                  # unblock the replica's loop
                q.put(SVC_STOP)
        eng.notify()

    def shutdown(self):
        # unblock hosted service replicas first: their threads block on
        # queue.get and would otherwise keep the interpreter alive
        for q in list(self._service_queues.values()):
            q.put(SVC_STOP)
        # cancel_futures: queued-but-unstarted payloads must not launch
        # after the session is closed
        self._pool.shutdown(wait=False, cancel_futures=True)

    # ----------------------------------------------------------------- stats
    @property
    def queue_depth(self) -> int:
        return len(self._futures)

    @property
    def free_cores(self) -> int:
        return max(0, self.workers - self._active)

    @property
    def total_cores(self) -> int:
        return self.workers


class RealFunctionExecutor(RealExecutorBase):
    """Dragon-style in-process function executor (thread pool). Also hosts
    service replicas (each occupies one worker thread for its lifetime —
    size ``workers`` above the replica count so batch tasks still flow)."""

    kind = "dragon"
    accepts_static = True
    supports_services = True

    def __init__(self, engine, nodes: int = 1, spec=None, workers: int = 4,
                 name: str = "dragon", **_):
        super().__init__(engine, name, workers, thread_prefix="dragon")

    def accepts(self, task: Task) -> bool:
        d = task.description
        if d.kind == "service":
            return d.nodes == 0
        return d.fn is not None and d.nodes == 0

    def _payload(self, task: Task):
        d = task.description
        if d.fn is None:
            return None
        kwargs = self._resume_kwargs(task, dict(d.kwargs))
        return d.fn(*d.args, **kwargs)


class RealPartitionExecutor(RealExecutorBase):
    """Flux-style co-scheduling executor: one task owns a partition (jax
    submesh) at a time; partitions run concurrently."""

    kind = "flux"
    accepts_static = True

    def __init__(self, engine, nodes: int = 1, spec=None,
                 partitions: int = 1, mesh=None, name: str = "flux", **_):
        self.partitions = (carve_submeshes(mesh, partitions)
                           if mesh is not None else [None] * partitions)
        super().__init__(engine, name, len(self.partitions),
                         thread_prefix="flux")
        self._part_q: "queue.Queue" = queue.Queue()
        for p in self.partitions:
            self._part_q.put(p)

    def accepts(self, task: Task) -> bool:
        return task.description.fn is not None

    def _payload(self, task: Task):
        part = self._part_q.get()        # co-schedule: own one partition
        try:
            d = task.description
            task.partition = getattr(part, "index", None)
            kwargs = dict(d.kwargs)
            if part is not None and _accepts_kw(d.fn, "mesh"):
                kwargs["mesh"] = part.mesh
            kwargs = self._resume_kwargs(task, kwargs)
            return d.fn(*d.args, **kwargs) if d.fn else None
        finally:
            self._part_q.put(part)


class SubprocessExecutor(RealExecutorBase):
    """Launches ``TaskDescription.executable`` + ``arguments`` as a host
    subprocess — the real analogue of launching executable tasks through a
    batch runtime. Nonzero exit codes fail the task (and feed the agent's
    retry path); stdout becomes ``task.result``."""

    kind = "popen"
    accepts_static = True

    def __init__(self, engine, nodes: int = 1, spec=None, workers: int = 4,
                 timeout: Optional[float] = None, name: str = "popen", **_):
        super().__init__(engine, name, workers, thread_prefix="popen")
        self.timeout = timeout

    def accepts(self, task: Task) -> bool:
        return bool(task.description.executable)

    def _payload(self, task: Task):
        d = task.description
        argv: List[str] = [d.executable, *map(str, d.arguments)]
        # per-task walltime actually kills the subprocess (unlike pure
        # python payloads, which are only failed cooperatively)
        timeout = d.walltime if d.walltime > 0.0 else self.timeout
        proc = subprocess.run(argv, capture_output=True, text=True,
                              timeout=timeout)
        if proc.returncode != 0:
            raise RuntimeError(
                f"exit {proc.returncode}: {proc.stderr.strip()[:500]}")
        return proc.stdout


def _funcpool_worker(task_q, result_q):
    """Persistent worker loop: pull one pickled *batch* of
    (uid, attempt, fn, args, kwargs) jobs per queue op, execute them
    in-process, and push one pickled batch of
    (uid, attempt, ok, result, t0, t1) records back — the
    mp.Queue round-trip (lock, pipe write, feeder wakeup) is paid once per
    batch instead of once per call, which is what moves the pool from the
    ~1-2k calls/s queue-bound regime toward the 10k+/s on-node rate the
    Dragon paper reports. Runs until the ``None`` sentinel. Payloads cross
    the queues as explicit pickle blobs so serialization errors surface
    synchronously at the pickling site instead of dying in a queue feeder
    thread. Lives at module level so it pickles under any multiprocessing
    start method."""
    import pickle

    while True:
        item = task_q.get()
        if item is None:
            break
        jobs = pickle.loads(item)
        out = []
        for uid, attempt, fn, args, kwargs in jobs:
            t0 = time.monotonic()
            try:
                result = fn(*args, **(kwargs or {}))
                ok = True
            except BaseException as e:                            # noqa: BLE001
                result = f"{type(e).__name__}: {e}"
                ok = False
            t1 = time.monotonic()
            out.append((uid, attempt, ok, result, t0, t1))
        try:
            blob = pickle.dumps(out)
        except Exception:                  # unpicklable result   # noqa: BLE001
            safe = []
            for uid, attempt, ok, result, t0, t1 in out:
                try:
                    pickle.dumps(result)
                except Exception as e:                            # noqa: BLE001
                    result, ok = f"unpicklable result: {e}", False
                safe.append((uid, attempt, ok, result, t0, t1))
            blob = pickle.dumps(safe)
        result_q.put(blob)


class FuncPoolExecutor(BaseExecutor):
    """Raptor/Dragon-style master/worker function execution over persistent
    OS processes: workers are spawned once at ``start()`` and dispatch
    happens over shared queues — executing a call never forks, so throughput
    is queue-bound instead of process-spawn-bound (~100/s), which is exactly
    the paper's function-mode speedup. Jobs cross the queue as *batched*
    pickle blobs (one blob per ``batch`` jobs per mp.Queue op) and the
    collector thread sizes its commits adaptively, so at saturation the
    per-call cost is a slice of one queue round-trip rather than a whole
    one. The collector converts worker completion records into
    task-pipeline transitions (timestamps mapped from the workers'
    CLOCK_MONOTONIC stamps onto the engine clock), committed under
    ``engine.lock`` like every other real backend."""

    kind = "funcpool"
    accepts_static = True

    def __init__(self, engine, nodes: int = 1, spec=None,
                 workers: Optional[int] = None, start_method: str = "",
                 batch: int = 128, name: str = "funcpool", **_):
        super().__init__(name)
        self.engine = engine
        self.workers = workers or min(4, os.cpu_count() or 1)
        # jobs pickled per mp.Queue op (one blob per batch, not per call);
        # a batch executes on one worker, so very uneven payload durations
        # may warrant a smaller batch to rebalance
        self.batch = max(1, batch)
        methods = mp.get_all_start_methods()
        self._ctx = mp.get_context(
            start_method or ("fork" if "fork" in methods else "spawn"))
        self._inflight: Dict[str, Task] = {}
        self._procs: List[mp.Process] = []
        self._task_q = None
        self._result_q = None
        self._collector: Optional[threading.Thread] = None

    # ------------------------------------------------------------- lifecycle
    def start(self) -> float:
        # mp.Queue, not SimpleQueue: its feeder thread makes put()
        # non-blocking, which matters because submits happen under
        # engine.lock — a bounded-pipe put blocking there while the
        # collector needs the same lock to drain results would deadlock
        self._task_q = self._ctx.Queue()
        self._result_q = self._ctx.Queue()
        for _ in range(self.workers):
            p = self._ctx.Process(target=_funcpool_worker,
                                  args=(self._task_q, self._result_q),
                                  daemon=True)
            p.start()
            self._procs.append(p)
        self._collector = threading.Thread(target=self._collect,
                                           name=f"{self.name}-collector",
                                           daemon=True)
        self._collector.start()
        self.alive = True
        return 0.0

    def accepts(self, task: Task) -> bool:
        d = task.description
        return d.kind == "function" and d.fn is not None and d.nodes == 0

    # ---------------------------------------------------------------- submit
    def submit(self, task: Task):
        """Called under ``engine.lock`` (agent dispatch tick)."""
        self._submit_batch([task])

    def submit_many(self, tasks: List[Task]):
        """Bulk path: the whole dispatch-tick bulk is pickled in job
        batches, one blob per mp.Queue op, so the queue overhead amortizes
        across the batch. A blob executes serially on one worker, so the
        batch size is capped at bulk/workers — a bulk smaller than
        ``batch x workers`` still spreads across the whole pool. A batch
        containing an unpicklable payload falls back to per-task
        submission so only the offending task fails."""
        n = len(tasks)
        batch = min(self.batch,
                    max(1, (n + self.workers - 1) // self.workers))
        for i in range(0, n, batch):
            self._submit_batch(tasks[i:i + batch])

    def _submit_batch(self, tasks: List[Task]):
        eng = self.engine
        import pickle
        for task in tasks:
            task.backend = self.name
        try:
            # explicit dumps: an unpicklable payload fails here,
            # synchronously, instead of dying in a queue feeder thread
            for t in tasks:
                t.attempt += 1
            blob = pickle.dumps([(t.uid, t.attempt, t.description.fn,
                                  t.description.args, t.description.kwargs)
                                 for t in tasks])
        except Exception as e:                                    # noqa: BLE001
            if len(tasks) > 1:             # isolate the offending payload
                for t in tasks:
                    self._submit_batch([t])
                return
            task = tasks[0]
            task.error = f"{self.name}: unpicklable payload: {e}"
            task.advance(TaskState.FAILED, eng.now(), eng.profiler)
            self.stats["failed"] += 1
            if self.on_failure:
                self.on_failure(task, task.error)
            eng.notify()
            return
        self._task_q.put(blob)
        inflight = self._inflight
        now = eng.now()
        profiler = eng.profiler
        for t in tasks:
            inflight[t.uid] = t
            t.advance(TaskState.LAUNCHING, now, profiler)
        self.stats["launched"] += len(tasks)

    def _collect(self):
        import pickle

        eng = self.engine
        result_q = self._result_q
        from_monotonic = eng.clock.from_monotonic
        stop = False
        target = 64
        while not stop:
            # accumulate records (each queue item is a batch) up to an
            # adaptive per-commit target: it doubles while the queue stays
            # hot — fewer lock acquisitions per record under load — and
            # shrinks toward 32 when results trickle, keeping latency low
            item = result_q.get()
            records = []
            if item is None:
                stop = True
            else:
                records.extend(pickle.loads(item))
            while len(records) < target and not result_q.empty():
                item = result_q.get()
                if item is None:
                    stop = True
                    break
                records.extend(pickle.loads(item))
            target = (min(target * 2, 2048) if len(records) >= target
                      else max(target // 2, 32))
            if not records:
                continue
            with eng.lock:
                for uid, attempt, ok, result, t0, t1 in records:
                    task = self._inflight.get(uid)
                    # the attempt guard keeps a stale record (task failed by
                    # chaos, requeued, resubmitted here as a newer attempt)
                    # from committing against the live attempt
                    if (task is None or task.done
                            or task.attempt != attempt):
                        continue
                    self._inflight.pop(uid, None)
                    task.advance(TaskState.RUNNING, from_monotonic(t0),
                                 eng.profiler)
                    if ok:
                        task.result = result
                        task.advance(TaskState.DONE, from_monotonic(t1),
                                     eng.profiler)
                        self.stats["completed"] += 1
                        if self.on_complete:
                            self.on_complete(task)
                    else:
                        task.error = str(result)
                        task.advance(TaskState.FAILED, from_monotonic(t1),
                                     eng.profiler)
                        self.stats["failed"] += 1
                        if self.on_failure:
                            self.on_failure(task, task.error)
            eng.notify()

    # ---------------------------------------------------------------- control
    def cancel(self, task: Task):
        """A job already in the shared queue cannot be recalled; mark the
        task terminal and the collector discards its eventual result."""
        eng = self.engine
        with eng.lock:
            self._inflight.pop(task.uid, None)
            if not task.done:
                task.advance(TaskState.CANCELED, eng.now(), eng.profiler)
        eng.notify()

    def fail_task(self, task: Task, reason: str = "executor kill") -> bool:
        """Fault injection: an in-flight mp job cannot be recalled or
        killed individually, so fail the task through on_failure and let
        the collector's attempt guard discard the worker's eventual record.
        Per-task walltime is likewise unenforceable on this backend — use
        the thread-pool backends for walltime-sensitive payloads."""
        eng = self.engine
        with eng.lock:
            if task.done:
                return False
            self._inflight.pop(task.uid, None)
            task.error = f"{self.name}: {reason}"
            task.advance(TaskState.FAILED, eng.now(), eng.profiler)
            self.stats["failed"] += 1
            if self.on_failure:
                self.on_failure(task, task.error)
        eng.notify()
        return True

    def running_tasks(self) -> List[Task]:
        with self.engine.lock:
            return list(self._inflight.values())

    def evacuate(self) -> List[Task]:
        """Pilot death: the worker processes die with the pilot, so every
        in-flight job fails through on_failure (nothing is recallable from
        the shared mp queue — no orphans to hand back)."""
        eng = self.engine
        with eng.lock:
            victims = list(self._inflight.values())
        for t in victims:
            self.fail_task(t, "executor failure")
        self.shutdown()
        return []

    def shutdown(self):
        if not self.alive:
            return
        self.alive = False
        for _ in self._procs:
            self._task_q.put(None)
        self._result_q.put(None)           # collector exits; late results drop
        for p in self._procs:
            p.join(timeout=2.0)
            if p.is_alive():
                p.terminate()
        if self._collector is not None:
            self._collector.join(timeout=1.0)

    # ------------------------------------------------------------------ stats
    @property
    def queue_depth(self) -> int:
        return len(self._inflight)

    @property
    def free_cores(self) -> int:
        return max(0, self.workers - len(self._inflight))

    @property
    def total_cores(self) -> int:
        return self.workers


@register_executor("dragon", mode="real")
def _build_real_dragon(engine, nodes=1, spec=None, **options):
    return RealFunctionExecutor(engine, nodes=nodes, spec=spec, **options)


@register_executor("funcpool", mode="real")
def _build_real_funcpool(engine, nodes=1, spec=None, **options):
    return FuncPoolExecutor(engine, nodes=nodes, spec=spec, **options)


@register_executor("flux", mode="real")
def _build_real_flux(engine, nodes=1, spec=None, **options):
    return RealPartitionExecutor(engine, nodes=nodes, spec=spec, **options)


@register_executor("popen", mode="real")
def _build_popen(engine, nodes=1, spec=None, **options):
    return SubprocessExecutor(engine, nodes=nodes, spec=spec, **options)
