"""Real-mode executor backends: the same BaseExecutor surface the simulator's
backend models implement, but payloads actually execute on this host.

Backends mirror the simulation split:
  * ``dragon`` — a worker-thread pool for in-process Python *function* tasks
    (Dragon's native mode: no process spawn per task, shared interpreter
    state / device buffers).
  * ``flux``   — co-scheduled *executable* tasks; each partition maps to a
    jax submesh (core/partition.py) and runs its tasks serially
    (co-scheduling: one tightly-coupled job owns the partition at a time).
    Task callables that declare a ``mesh`` keyword receive their partition's
    submesh.
  * ``popen``  — external executables launched as subprocesses
    (``TaskDescription.executable`` + ``arguments``); stdout becomes
    ``task.result``.

All task state transitions are committed under ``engine.lock`` and followed
by ``engine.notify()``, so the agent's single-threaded lifecycle logic
(retries, speculation, campaign stage release) runs unchanged on top.
"""
from __future__ import annotations

import inspect
import queue
import subprocess
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Dict, List, Optional

from repro.core.executors.base import BaseExecutor
from repro.core.partition import carve_submeshes
from repro.core.task import Task, TaskState
from repro.runtime.registry import register_executor


def _accepts_kw(fn, name: str) -> bool:
    if fn is None:
        return False
    try:
        return name in inspect.signature(fn).parameters
    except (TypeError, ValueError):
        return False


class RealExecutorBase(BaseExecutor):
    """Thread-pool executor skeleton: queueing, cancellation, and locked
    state commits; subclasses provide ``_payload``."""

    def __init__(self, engine, name: str, workers: int,
                 thread_prefix: str = "worker"):
        super().__init__(name)
        self.engine = engine
        self.workers = workers
        self._pool = ThreadPoolExecutor(max_workers=max(1, workers),
                                        thread_name_prefix=thread_prefix)
        self._futures: Dict[str, Future] = {}
        self._active = 0

    # ------------------------------------------------------------- lifecycle
    def start(self) -> float:
        self.alive = True
        return 0.0

    def submit(self, task: Task):
        task.backend = self.name
        try:
            self._futures[task.uid] = self._pool.submit(self._run, task)
        except RuntimeError as e:       # pool shut down (session closed)
            eng = self.engine
            task.error = f"{self.name}: {e}"
            task.advance(TaskState.FAILED, eng.now(), eng.profiler)
            self.stats["failed"] += 1
            if self.on_failure:
                self.on_failure(task, task.error)
            eng.notify()

    def _run(self, task: Task):
        eng = self.engine
        with eng.lock:
            self._futures.pop(task.uid, None)
            if task.done:                         # canceled while queued
                return
            self._active += 1
            task.advance(TaskState.LAUNCHING, eng.now(), eng.profiler)
            task.advance(TaskState.RUNNING, eng.now(), eng.profiler)
            self.stats["launched"] += 1
        try:
            result = self._payload(task)
        except Exception as e:                                # noqa: BLE001
            err = f"{type(e).__name__}: {e}"
            with eng.lock:
                self._active -= 1
                if not task.done:
                    task.error = err
                    task.advance(TaskState.FAILED, eng.now(), eng.profiler)
                    self.stats["failed"] += 1
                    if self.on_failure:
                        self.on_failure(task, err)
            eng.notify()
            return
        with eng.lock:
            self._active -= 1
            if not task.done:                     # may have been CANCELED
                task.result = result
                task.advance(TaskState.DONE, eng.now(), eng.profiler)
                self.stats["completed"] += 1
                if self.on_complete:
                    self.on_complete(task)
        eng.notify()

    def _payload(self, task: Task):
        raise NotImplementedError

    # --------------------------------------------------------------- control
    def cancel(self, task: Task):
        eng = self.engine
        with eng.lock:
            fut = self._futures.pop(task.uid, None)
            if fut is not None:
                fut.cancel()
            if not task.done:
                # a still-running payload sees the terminal state at commit
                # time and discards its result
                task.advance(TaskState.CANCELED, eng.now(), eng.profiler)
        eng.notify()

    def shutdown(self):
        self._pool.shutdown(wait=False)

    # ----------------------------------------------------------------- stats
    @property
    def queue_depth(self) -> int:
        return len(self._futures)

    @property
    def free_cores(self) -> int:
        return max(0, self.workers - self._active)

    @property
    def total_cores(self) -> int:
        return self.workers


class RealFunctionExecutor(RealExecutorBase):
    """Dragon-style in-process function executor (thread pool)."""

    kind = "dragon"
    accepts_static = True

    def __init__(self, engine, nodes: int = 1, spec=None, workers: int = 4,
                 name: str = "dragon", **_):
        super().__init__(engine, name, workers, thread_prefix="dragon")

    def accepts(self, task: Task) -> bool:
        d = task.description
        return d.fn is not None and d.nodes == 0

    def _payload(self, task: Task):
        d = task.description
        return d.fn(*d.args, **dict(d.kwargs)) if d.fn else None


class RealPartitionExecutor(RealExecutorBase):
    """Flux-style co-scheduling executor: one task owns a partition (jax
    submesh) at a time; partitions run concurrently."""

    kind = "flux"
    accepts_static = True

    def __init__(self, engine, nodes: int = 1, spec=None,
                 partitions: int = 1, mesh=None, name: str = "flux", **_):
        self.partitions = (carve_submeshes(mesh, partitions)
                           if mesh is not None else [None] * partitions)
        super().__init__(engine, name, len(self.partitions),
                         thread_prefix="flux")
        self._part_q: "queue.Queue" = queue.Queue()
        for p in self.partitions:
            self._part_q.put(p)

    def accepts(self, task: Task) -> bool:
        return task.description.fn is not None

    def _payload(self, task: Task):
        part = self._part_q.get()        # co-schedule: own one partition
        try:
            d = task.description
            task.partition = getattr(part, "index", None)
            kwargs = dict(d.kwargs)
            if part is not None and _accepts_kw(d.fn, "mesh"):
                kwargs["mesh"] = part.mesh
            return d.fn(*d.args, **kwargs) if d.fn else None
        finally:
            self._part_q.put(part)


class SubprocessExecutor(RealExecutorBase):
    """Launches ``TaskDescription.executable`` + ``arguments`` as a host
    subprocess — the real analogue of launching executable tasks through a
    batch runtime. Nonzero exit codes fail the task (and feed the agent's
    retry path); stdout becomes ``task.result``."""

    kind = "popen"
    accepts_static = True

    def __init__(self, engine, nodes: int = 1, spec=None, workers: int = 4,
                 timeout: Optional[float] = None, name: str = "popen", **_):
        super().__init__(engine, name, workers, thread_prefix="popen")
        self.timeout = timeout

    def accepts(self, task: Task) -> bool:
        return bool(task.description.executable)

    def _payload(self, task: Task):
        d = task.description
        argv: List[str] = [d.executable, *map(str, d.arguments)]
        proc = subprocess.run(argv, capture_output=True, text=True,
                              timeout=self.timeout)
        if proc.returncode != 0:
            raise RuntimeError(
                f"exit {proc.returncode}: {proc.stderr.strip()[:500]}")
        return proc.stdout


@register_executor("dragon", mode="real")
def _build_real_dragon(engine, nodes=1, spec=None, **options):
    return RealFunctionExecutor(engine, nodes=nodes, spec=spec, **options)


@register_executor("flux", mode="real")
def _build_real_flux(engine, nodes=1, spec=None, **options):
    return RealPartitionExecutor(engine, nodes=nodes, spec=spec, **options)


@register_executor("popen", mode="real")
def _build_popen(engine, nodes=1, spec=None, **options):
    return SubprocessExecutor(engine, nodes=nodes, spec=spec, **options)
