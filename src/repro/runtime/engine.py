"""Execution engines: the pluggable substrate under the Agent.

An :class:`Engine` bundles everything the agent's dispatch pipeline and the
executors need from their environment — a clock, an event scheduler, a
profiler, seeded noise, and platform-level srun slot accounting — behind one
interface, so the *same* task-management code (routing, retries, speculation,
campaigns) runs on either implementation:

* :class:`SimEngine`  — discrete-event virtual clock (paper-scale simulation,
  4-1024 node allocations, deterministic).
* :class:`RealEngine` — wall clock + timer threads; payloads actually execute
  on this host. All runtime callbacks are serialized under ``engine.lock`` so
  the single-threaded agent logic holds unchanged.

This mirrors RADICAL-Pilot's layering (arXiv:2103.00091): one task-management
pipeline over interchangeable runtime backends.
"""
from __future__ import annotations

import gc
import math
import random
import threading
import time
from abc import ABC, abstractmethod
from typing import Callable, Optional

import numpy as np

from repro.core import calibration as CAL
from repro.core.events import Profiler
from repro.core.simclock import RealClock, VirtualClock


class Engine(ABC):
    """Shared runtime state: clock, trace, seeded noise, srun slots.

    ``mode`` selects which executor implementations the registry builds
    ("sim" -> discrete-event models, "real" -> thread/subprocess backends).
    """

    mode: str = "sim"
    startup_overhead_s: float = 0.0

    def __init__(self, seed: int = 0,
                 srun_cap: int = CAL.SRUN_CONCURRENCY_CAP):
        self.profiler = Profiler()
        self.rng = random.Random(seed)
        # seeded normal-deviate buffer for `noisy`: numpy fills 8k draws at
        # C speed; random.gauss was ~1.3us per sampled launch on the hot
        # path
        self._np_rng = np.random.default_rng(seed)
        self._normal_buf = None
        self._normal_pos = 0
        self.srun_cap = srun_cap
        self._srun_used = 0
        self.duration_fn: Optional[Callable] = None
        # serializes all runtime callbacks; uncontended (same-thread) in sim
        self.lock = threading.RLock()

    # ------------------------------------------------------------------ time
    def now(self) -> float:
        return self.clock.now()

    @property
    def events_fired(self) -> int:
        """Total scheduler events fired so far (0 on wall-clock engines);
        benchmarks report sim-events/s from this."""
        return getattr(self.clock, "fired_total", 0)

    @abstractmethod
    def schedule(self, delay: float, fn: Callable, *args):
        """Run ``fn(*args)`` after ``delay`` engine-seconds."""

    def call_soon(self, fn: Callable, *args):
        """Run ``fn(*args)`` as soon as the engine is idle: after the
        current event on the sim engine (same virtual time, deterministic
        order), on a prompt timer on the real engine. The campaign
        scheduler coalesces its placement passes through this."""
        return self.schedule(0.0, fn, *args)

    @abstractmethod
    def drain(self, predicate: Optional[Callable[[], bool]] = None,
              timeout: Optional[float] = None,
              max_events: int = 50_000_000) -> bool:
        """Advance the engine until ``predicate()`` holds. Returns the final
        predicate value (True when no predicate is given).

        ``timeout`` is *wall-clock* seconds: it bounds how long a RealEngine
        blocks. A SimEngine runs at virtual speed and is bounded by
        ``max_events`` instead — it drains its event heap regardless of
        ``timeout``. Callback exceptions propagate out of drain on both
        engines."""

    def notify(self):
        """Wake ``drain`` waiters after out-of-band state changes."""

    def shutdown(self):
        """Release engine resources (timers, pools)."""

    # ----------------------------------------------------------------- noise
    def noisy(self, mean: float, sigma: float = 0.0) -> float:
        if sigma <= 0:
            return mean
        buf = self._normal_buf
        pos = self._normal_pos
        if buf is None or pos >= 8192:
            buf = self._normal_buf = self._np_rng.standard_normal(8192)
            pos = 0
        self._normal_pos = pos + 1
        return mean * math.exp(sigma * buf[pos])

    def actual_duration(self, task) -> float:
        if self.duration_fn is not None:
            dur = max(0.0, self.duration_fn(task))
        else:
            dur = task.description.duration
        # checkpoint-aware restart: progress persisted by a prior attempt
        # shortens the rerun instead of restarting from zero
        progress = getattr(task, "progress", 0.0)
        if progress > 0.0:
            dur = max(dur - progress, 1e-6)
        return dur

    # --- platform srun slot accounting (Frontier cap, §4.1.1) ---------------
    @property
    def srun_slots_free(self) -> int:
        return self.srun_cap - self._srun_used

    def take_srun_slot(self):
        assert self._srun_used < self.srun_cap, "srun cap violated"
        self._srun_used += 1

    def release_srun_slot(self):
        self._srun_used = max(0, self._srun_used - 1)


class SimEngine(Engine):
    """Discrete-event engine: virtual clock + seeded noise (paper scale)."""

    mode = "sim"
    startup_overhead_s = CAL.AGENT_STARTUP_S

    def __init__(self, seed: int = 0,
                 srun_cap: int = CAL.SRUN_CONCURRENCY_CAP):
        super().__init__(seed, srun_cap)
        self.clock = VirtualClock()
        if type(self) is SimEngine:
            # bypass the delegation layer on the two hottest engine calls
            # (subclasses that override now/schedule keep their methods)
            self.now = self.clock.now
            self.schedule = self.clock.schedule

    def schedule(self, delay: float, fn: Callable, *args):
        return self.clock.schedule(delay, fn, *args)

    def drain(self, predicate: Optional[Callable[[], bool]] = None,
              timeout: Optional[float] = None,
              max_events: int = 50_000_000) -> bool:
        # timeout is a wall-clock bound (see Engine.drain): the virtual
        # clock drains its whole heap, bounded by max_events.
        # The sim allocates no reference cycles in steady state, so pause
        # the cyclic GC for the drain — generational collections otherwise
        # rescan millions of live tasks/trace rows (~25% of wall time on a
        # 100k-task campaign).
        was_enabled = gc.isenabled()
        if was_enabled:
            gc.disable()
        try:
            self.clock.run(max_events=max_events)
        finally:
            if was_enabled:
                gc.enable()
        return predicate() if predicate is not None else True


class RealEngine(Engine):
    """Wall-clock engine: timers + worker threads executing real payloads.

    Every scheduled callback runs holding ``self.lock``; executors commit
    task state transitions under the same lock, so agent/campaign logic sees
    the exact serialization discipline the simulator provides for free.
    """

    mode = "real"
    startup_overhead_s = 0.0

    def __init__(self, seed: int = 0,
                 srun_cap: int = CAL.SRUN_CONCURRENCY_CAP):
        super().__init__(seed, srun_cap)
        self.clock = RealClock()
        self._cond = threading.Condition(self.lock)
        self._callback_error: Optional[BaseException] = None

    def schedule(self, delay: float, fn: Callable, *args):
        def fire():
            with self._cond:
                try:
                    fn(*args)
                except BaseException as e:      # noqa: BLE001
                    # timer threads must not swallow errors: stash the first
                    # one and re-raise it from drain() (sim-mode parity,
                    # where callback errors propagate out of clock.run)
                    if self._callback_error is None:
                        self._callback_error = e
                self._cond.notify_all()
        return self.clock.schedule(delay, fire)

    def notify(self):
        with self._cond:
            self._cond.notify_all()

    def _check_error(self):
        if self._callback_error is not None:
            err, self._callback_error = self._callback_error, None
            raise err

    def drain(self, predicate: Optional[Callable[[], bool]] = None,
              timeout: Optional[float] = None,
              max_events: int = 50_000_000) -> bool:
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cond:
            self._check_error()
            if predicate is None:
                return True
            while not predicate():
                # short re-check interval guards against missed wakeups
                wait_s = 0.1
                if deadline is not None:
                    wait_s = min(wait_s, deadline - time.monotonic())
                    if wait_s <= 0:
                        return predicate()
                self._cond.wait(wait_s)
                self._check_error()
            return True

    def shutdown(self):
        self.clock.cancel_all()
