"""repro.runtime — the pluggable execution substrate.

Layering (see README.md in this directory):

    Session -> PilotManager -> Pilot -> Agent -> Executor backends
                                  |        |
                              Engine (SimEngine | RealEngine)

The same Agent pipeline (routing, retries, speculation, campaigns) runs over
either engine; executor backends are resolved through the registry, so new
backends plug in with ``@register_executor`` and no agent edits.
"""
from repro.runtime.engine import Engine, RealEngine, SimEngine
from repro.runtime.registry import (available_executors, create_executor,
                                    register_executor, unregister_executor)
from repro.runtime.real_executors import (FuncPoolExecutor,
                                          RealExecutorBase,
                                          RealFunctionExecutor,
                                          RealPartitionExecutor,
                                          SubprocessExecutor)
from repro.runtime.session import PilotManager, Session, TaskManager
from repro.services import (LeastOutstandingBalancer, RoundRobinBalancer,
                            Service)

__all__ = [
    "Engine", "SimEngine", "RealEngine",
    "register_executor", "unregister_executor", "create_executor",
    "available_executors",
    "RealExecutorBase", "RealFunctionExecutor", "RealPartitionExecutor",
    "SubprocessExecutor", "FuncPoolExecutor",
    "Session", "PilotManager", "TaskManager",
    "Service", "RoundRobinBalancer", "LeastOutstandingBalancer",
]
