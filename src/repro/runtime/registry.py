"""Executor backend registry.

New runtime backends plug into the agent without editing ``agent.py``:

    from repro.runtime.registry import register_executor

    @register_executor("mybackend", mode="sim")
    def build(engine, nodes, spec, **options):
        return MyExecutor(engine, nodes, spec, **options)

``Agent._build_backends`` resolves ``{"mybackend": {...options}}`` through
:func:`create_executor`, keyed on the engine's ``mode`` ("sim" / "real");
a factory registered under ``mode="any"`` serves both. Built-in backends
(sim: flux/dragon/srun/funcpool; real: flux/dragon/popen/funcpool)
self-register on import.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Tuple

ExecutorFactory = Callable[..., object]

_REGISTRY: Dict[Tuple[str, str], ExecutorFactory] = {}
_builtins_loaded = False


def register_executor(name: str, mode: str = "sim"
                      ) -> Callable[[ExecutorFactory], ExecutorFactory]:
    """Decorator registering ``factory(engine, nodes, spec, **options)``
    as the constructor for backend ``name`` under engine ``mode``."""
    def deco(factory: ExecutorFactory) -> ExecutorFactory:
        _REGISTRY[(mode, name)] = factory
        return factory
    return deco


def unregister_executor(name: str, mode: str = "sim"):
    _REGISTRY.pop((mode, name), None)


def _ensure_builtins():
    global _builtins_loaded
    if _builtins_loaded:
        return
    _builtins_loaded = True
    # importing the modules triggers their @register_executor decorators
    import repro.core.executors.dragon    # noqa: F401
    import repro.core.executors.flux      # noqa: F401
    import repro.core.executors.funcpool  # noqa: F401
    import repro.core.executors.srun      # noqa: F401
    import repro.runtime.real_executors   # noqa: F401


def available_executors(mode: str) -> List[str]:
    _ensure_builtins()
    return sorted({n for m, n in _REGISTRY if m in (mode, "any")})


def create_executor(name: str, engine, nodes: int, spec, **options):
    """Build backend ``name`` for ``engine`` (dispatch on ``engine.mode``)."""
    _ensure_builtins()
    factory = (_REGISTRY.get((engine.mode, name))
               or _REGISTRY.get(("any", name)))
    if factory is None:
        raise KeyError(
            f"no executor {name!r} registered for mode {engine.mode!r} "
            f"(available: {available_executors(engine.mode)})")
    return factory(engine, nodes=nodes, spec=spec, **options)
