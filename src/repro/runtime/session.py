"""RADICAL-Pilot-style top-level API: Session -> PilotManager -> TaskManager.

    from repro.runtime import Session, PilotManager, TaskManager
    from repro.core.pilot import PilotDescription
    from repro.core.task import TaskDescription

    with Session(mode="sim", seed=0) as session:        # or mode="real"
        pmgr  = PilotManager(session)
        tmgr  = TaskManager(session)
        pilot = pmgr.submit_pilots(PilotDescription(
            nodes=4, backends={"flux": {"partitions": 2}}))
        tmgr.add_pilots(pilot)
        tasks = tmgr.submit_tasks([TaskDescription(duration=180.0)
                                   for _ in range(100)])
        tmgr.wait_tasks()

The session owns the engine (the pluggable substrate: simulated or real);
pilots wrap resource acquisition in their own state machine (NEW ->
LAUNCHING -> ACTIVE -> DONE) and each ACTIVE pilot runs one Agent; the task
manager routes task submissions to pilot agents and blocks on completion.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Union

from repro.core.pilot import Pilot, PilotDescription, PilotState
from repro.core.task import DescriptionBatch, Task, TaskDescription, new_uid
from repro.runtime.engine import Engine, RealEngine, SimEngine


class Session:
    """Root object: owns the engine and all managers; ``close()`` (or the
    context manager) tears down pilots, executors, and engine timers."""

    def __init__(self, mode: str = "sim", seed: int = 0,
                 engine: Optional[Engine] = None, uid: str = ""):
        if engine is not None:
            self.engine = engine
        elif mode == "sim":
            self.engine = SimEngine(seed=seed)
        elif mode == "real":
            self.engine = RealEngine(seed=seed)
        else:
            raise KeyError(f"unknown session mode {mode!r}")
        self.uid = uid or new_uid("session")
        self.closed = False
        self._pmgrs: List["PilotManager"] = []
        self._tmgrs: List["TaskManager"] = []
        self.engine.profiler.record(self.engine.now(), self.uid,
                                    "session:start",
                                    {"mode": self.engine.mode})

    @property
    def mode(self) -> str:
        return self.engine.mode

    @property
    def profiler(self):
        return self.engine.profiler

    def pilots(self) -> List[Pilot]:
        return [p for m in self._pmgrs for p in m.pilots]

    def close(self):
        if self.closed:
            return
        self.closed = True
        with self.engine.lock:
            now = self.engine.now()
            for pilot in self.pilots():
                if pilot.state == PilotState.LAUNCHING:
                    pilot.advance(PilotState.CANCELED, now,
                                  self.engine.profiler)
                elif pilot.state == PilotState.ACTIVE:
                    pilot.advance(PilotState.DONE, now, self.engine.profiler)
                agent = getattr(pilot, "agent", None)
                if agent is not None:
                    for ex in agent.backends.values():
                        ex.shutdown()
            self.engine.profiler.record(now, self.uid, "session:close", {})
        self.engine.shutdown()

    def __enter__(self) -> "Session":
        return self

    def __exit__(self, *exc):
        self.close()
        return False


class PilotManager:
    """Manages pilot lifecycles: ``submit_pilots`` acquires resources
    (constructs the agent over the session engine) and drives the pilot
    state machine; activation is stamped at agent readiness."""

    def __init__(self, session: Session, uid: str = ""):
        self.session = session
        self.uid = uid or new_uid("pmgr")
        self.pilots: List[Pilot] = []
        session._pmgrs.append(self)

    def submit_pilots(self, descriptions: Union[PilotDescription,
                                                Sequence[PilotDescription]],
                      **agent_options) -> Union[Pilot, List[Pilot]]:
        """Launch pilot(s). ``agent_options`` (policy=, speculation=,
        dispatch_rate=, dispatch_batch=, ...) pass through to the Agent."""
        # deferred import: repro.core.agent imports this package at load time
        from repro.core.agent import Agent

        single = isinstance(descriptions, PilotDescription)
        descs = [descriptions] if single else list(descriptions)
        engine = self.session.engine
        out = []
        for pd in descs:
            pilot = Pilot(pd)
            with engine.lock:
                pilot.advance(PilotState.LAUNCHING, engine.now(),
                              engine.profiler)
                agent = Agent(engine, pd.nodes, pd.backends,
                              node_spec=pd.node_spec, **agent_options)
                agent.start()
                pilot.agent = agent
                delay = max(0.0, agent.ready_at - engine.now())
                engine.schedule(delay, self._activate, pilot)
            self.pilots.append(pilot)
            out.append(pilot)
        return out[0] if single else out

    def _activate(self, pilot: Pilot):
        if pilot.state == PilotState.LAUNCHING:
            pilot.advance(PilotState.ACTIVE, self.session.engine.now(),
                          self.session.engine.profiler)

    def cancel_pilots(self, pilots: Optional[Sequence[Pilot]] = None):
        engine = self.session.engine
        with engine.lock:
            for pilot in (pilots if pilots is not None else self.pilots):
                if pilot.state in (PilotState.NEW, PilotState.LAUNCHING,
                                   PilotState.ACTIVE):
                    pilot.advance(PilotState.CANCELED, engine.now(),
                                  engine.profiler)


class TaskManager:
    """Routes task submissions to pilot agents through a campaign
    scheduler (repro.sched) and waits on completion. The default scheduler
    is FIFO passthrough — seed-equivalent least-loaded-pilot bulk
    submission — while ``scheduler=CampaignScheduler(policy=...)`` turns
    on hierarchical scheduling (priority/fair-share ordering, placement
    admission, backfill, gang reservations) for everything this manager
    submits: executables, gangs, funcpool functions, and service
    replicas."""

    def __init__(self, session: Session, uid: str = "",
                 scheduler=None):
        self.session = session
        self.uid = uid or new_uid("tmgr")
        self._pilots: List[Pilot] = []
        self.tasks: Dict[str, Task] = {}
        self._waves: List[Any] = []       # CohortWaves (columnar bulks)
        self._scheduler = scheduler
        session._tmgrs.append(self)

    @property
    def scheduler(self):
        """The campaign scheduler every submission routes through (built
        lazily as FIFO passthrough unless one was injected)."""
        if self._scheduler is None:
            from repro.sched import CampaignScheduler
            self._scheduler = CampaignScheduler()
        return self._scheduler

    def add_pilots(self, pilots: Union[Pilot, Sequence[Pilot]]):
        for p in ([pilots] if isinstance(pilots, Pilot) else list(pilots)):
            if p not in self._pilots:
                self._pilots.append(p)
                self.scheduler.add_pilot(p)

    @property
    def agent(self):
        """The (single) bound pilot's agent — campaign entry point."""
        if len(self._pilots) != 1:
            raise RuntimeError(f"{self.uid}: .agent needs exactly one pilot "
                               f"(have {len(self._pilots)})")
        return self._pilots[0].agent

    def submit_tasks(self, descriptions: Union[TaskDescription,
                                               Sequence[TaskDescription],
                                               DescriptionBatch]
                     ) -> Union[Task, List[Task], Any]:
        if isinstance(descriptions, DescriptionBatch):
            return self.submit_batch(descriptions)
        single = isinstance(descriptions, TaskDescription)
        descs = [descriptions] if single else list(descriptions)
        if self.session.closed:
            raise RuntimeError(f"{self.uid}: session {self.session.uid} "
                               f"is closed")
        if not self._pilots:
            raise RuntimeError(f"{self.uid}: no pilots added")
        # the scheduler owns pilot choice: FIFO passthrough reproduces the
        # seed least-loaded bulk path; gated policies hold tasks in their
        # queue and release on placement (engine.lock is taken inside)
        tasks = self.scheduler.submit(descs)
        if not isinstance(tasks, list):
            # cohort fast path: the bulk stays columnar (a CohortWave) —
            # registering a million per-uid entries would defeat it
            self._waves.append(tasks)
            return tasks
        for t in tasks:
            self.tasks[t.uid] = t
        return tasks[0] if single else tasks

    def submit_batch(self, batch: DescriptionBatch):
        """Submit a columnar :class:`DescriptionBatch` through the campaign
        scheduler: passthrough hands the whole batch to the least-loaded
        pilot (cohort-planned when eligible, bulk object ingestion over
        lazy row views otherwise); gated policies hold it as row-index
        slices and release on placement. Returns a ``CohortWave``, a task
        list, or the scheduler's batch handle — all waitable via
        ``wait_tasks``."""
        if self.session.closed:
            raise RuntimeError(f"{self.uid}: session {self.session.uid} "
                               f"is closed")
        if not self._pilots:
            raise RuntimeError(f"{self.uid}: no pilots added")
        tasks = self.scheduler.submit(batch)
        if not isinstance(tasks, list):
            self._waves.append(tasks)      # CohortWave or _BatchRef (.done)
            return tasks
        for t in tasks:
            self.tasks[t.uid] = t
        return tasks

    def submit_wave(self, template: TaskDescription, n: int):
        """Bulk-submit ``n`` clones of ``template`` as one all-scalar
        :class:`DescriptionBatch` (columnar, O(1) memory per task at
        submit), preferring the cohort fast path. Falls back to object
        tasks over lazy row views when the wave is not cohort-eligible.
        Returns a ``CohortWave`` or list."""
        if n <= 0:
            return []
        return self.submit_batch(DescriptionBatch.from_template(template, n))

    # ------------------------------------------------------------- services
    def start_service(self, handler=None, *, replicas: int = 2,
                      cores: int = 1, gpus: int = 0, nodes: int = 0,
                      startup: float = 0.0, rate: float = 0.0,
                      balancer="round-robin", backend: Optional[str] = None,
                      name: str = "", workflow: str = "",
                      max_retries: int = 2, restart=None, scale=None):
        """Provision ``replicas`` persistent service tasks on the bound
        pilot and return the :class:`repro.services.Service` handle. The
        replica tasks flow through the normal dispatch pipeline and are
        tracked by this manager (``wait_tasks`` covers them); route requests
        with ``service.request(payload)`` / ``submit_requests`` — they are
        buffered until the replicas are READY — and finish with
        ``service.stop()``. The fault model is configured here too:
        ``max_retries`` bounds request requeue on replica death, ``restart``
        takes a :class:`repro.services.RestartPolicy` (replace dead
        replicas), ``scale`` a :class:`repro.services.ScalePolicy` (elastic
        replica count from the queue-depth signal)."""
        from repro.services import Service

        svc = Service(self.agent, handler=handler, replicas=replicas,
                      cores=cores, gpus=gpus, nodes=nodes, startup=startup,
                      rate=rate, balancer=balancer, backend=backend,
                      name=name, workflow=workflow, max_retries=max_retries,
                      restart=restart, scale=scale,
                      submitter=self.scheduler)
        self.submit_tasks(svc.descriptions())
        return svc

    def watch(self, interval: float = 1.0, **watcher_kw):
        """Attach streaming telemetry to the bound pilot's run: returns a
        started :class:`repro.observability.stream.Watcher` whose engine
        callback folds the trace every ``interval`` (virtual seconds on a
        sim session, wall seconds on a real one). Keyword args pass
        through — ``rules=``, ``services=``, ``emit=``, ``promfile=``,
        ``on_tick=``, ``dt=``. Works on both engines; the watcher
        auto-finalizes when the agent drains."""
        # deferred import: observability is an optional consumer layer
        from repro.observability.stream import Watcher

        return Watcher(self.agent, interval=interval, **watcher_kw).start()

    def submit_functions(self, fn, argslist, **td_kw) -> List[Task]:
        """Submit one function task per element of ``argslist`` (each element
        becomes the positional args; non-tuples are wrapped). With a
        ``funcpool`` backend configured these execute inside persistent
        workers — the paper's high-throughput function path."""
        descs = [TaskDescription(kind="function", fn=fn,
                                 args=a if isinstance(a, tuple) else (a,),
                                 **td_kw)
                 for a in argslist]
        return self.submit_tasks(descs)

    def wait_tasks(self, tasks: Optional[Sequence[Task]] = None,
                   timeout: Optional[float] = None) -> bool:
        """Block until the given tasks (default: all submitted through this
        manager) reach a terminal state. Sim engines drain their event heap;
        real engines wait on wall-clock completion."""
        watched = list(tasks) if tasks is not None else None

        def finished() -> bool:
            if watched is not None:
                return all(t.done for t in watched)
            return (all(w.done for w in self._waves)
                    and all(t.done for t in self.tasks.values()))

        return self.session.engine.drain(finished, timeout=timeout)

    def run_campaign(self, stages, name: str = "campaign",
                     timeout: Optional[float] = None):
        """Convenience: run a Campaign through this manager's scheduler
        (stage priorities/tenants and ``barrier=False`` per-task release
        apply) and block until it completes. Returns the Campaign."""
        from repro.core.campaign import Campaign

        sched = self.scheduler
        camp = Campaign(sched, stages, name=name)
        with self.session.engine.lock:
            camp.start()
        self.session.engine.drain(
            lambda: sched.n_unfinished == 0 and camp.complete,
            timeout=timeout)
        return camp
