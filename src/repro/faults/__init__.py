"""Fault injection and recovery characterization for the task runtime."""
from repro.faults.chaos import ChaosController, FaultEvent, FaultPlan

__all__ = ["ChaosController", "FaultEvent", "FaultPlan"]
