"""Chaos engineering for the task runtime: planned node and pilot faults.

The paper's runtime comparison is framed around *sustained* throughput on
machines where node MTBF at scale makes failures routine; a runtime that
only performs on a healthy machine does not reproduce the operating point.
This module injects the two failure domains above single-task faults:

* **node failure** — a node leaves its backend's ``NodePool`` for good:
  every task with an allocation touching it fails through ``on_failure``
  (feeding the agent's retry/backoff path), and the campaign scheduler's
  placement view shrinks (``CampaignScheduler.on_node_failure``) so
  admission respects the degraded capacity. Real-mode backends have no
  node pools; they emulate the loss by dropping one worker and failing one
  running payload.
* **pilot failure** — a whole pilot dies: its agent evacuates every
  non-terminal task and the scheduler requeues all of them onto surviving
  pilots (``CampaignScheduler.fail_pilot``), recording per-task
  ``sched:requeue`` lineage.

Faults are described by a :class:`FaultPlan` (explicit events, a Poisson
process, or a target node-loss fraction) and driven by a
:class:`ChaosController`, which schedules every event on the pilot
engine — discrete events under ``SimEngine``, timer callbacks under
``RealEngine`` — so one plan runs identically on both engines. All
randomness comes from the controller's own seeded RNG, never from
``engine.rng``, so injecting chaos does not perturb the golden traces of
the underlying workload model.
"""
from __future__ import annotations

import random
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

# trace-name registry (entity = "chaos"): injection events recorded by the
# controller; `chaos:pilot_fail` is recorded by CampaignScheduler.fail_pilot
# (entity = scheduler uid) and re-exported here so the observability layer
# has one registry per failure domain
TRACE_NAMES: Dict[str, str] = {
    "node_fail": "chaos:node_fail",
    "pilot_fail": "chaos:pilot_fail",
    "skip": "chaos:skip",
}


@dataclass(frozen=True)
class FaultEvent:
    """One planned fault.

    ``t`` is seconds after :meth:`ChaosController.arm` (engine clock).
    ``pilot`` is a scheduler view index, or -1 to pick a live pilot at
    random. For node faults, ``backend`` restricts the target backend by
    name ("" = any) and ``node`` pins a pool node id (-1 = random live
    node on the chosen backend).
    """

    t: float
    kind: str                      # "node" | "pilot"
    pilot: int = -1
    backend: str = ""
    node: int = -1

    def __post_init__(self):
        if self.kind not in ("node", "pilot"):
            raise ValueError(f"unknown fault kind {self.kind!r}")
        if self.t < 0.0:
            raise ValueError("fault time must be >= 0")


@dataclass
class FaultPlan:
    """An ordered set of fault events; build explicitly or generate."""

    events: List[FaultEvent] = field(default_factory=list)

    def __iter__(self):
        return iter(sorted(self.events, key=lambda e: e.t))

    def __len__(self):
        return len(self.events)

    def add(self, event: FaultEvent) -> "FaultPlan":
        self.events.append(event)
        return self

    def merge(self, other: "FaultPlan") -> "FaultPlan":
        return FaultPlan(self.events + other.events)

    # ------------------------------------------------------------ generators
    @classmethod
    def node_loss(cls, n_nodes: int, fraction: float, horizon: float,
                  seed: int = 0, pilot: int = -1,
                  backend: str = "") -> "FaultPlan":
        """Lose ``fraction`` of ``n_nodes`` at uniform-random times in
        (0, horizon) — the acceptance shape: a campaign under 5-15% node
        loss. Victim nodes are left to the controller (-1 = random live)."""
        if not 0.0 <= fraction <= 1.0:
            raise ValueError("fraction must be in [0, 1]")
        rng = random.Random(seed)
        k = int(round(n_nodes * fraction))
        times = sorted(rng.uniform(horizon * 0.02, horizon)
                       for _ in range(k))
        return cls([FaultEvent(t, "node", pilot=pilot, backend=backend)
                    for t in times])

    @classmethod
    def poisson(cls, horizon: float, node_mtbf: Optional[float] = None,
                pilot_mtbf: Optional[float] = None,
                seed: int = 0) -> "FaultPlan":
        """Memoryless failure processes with the given mean times between
        failures, truncated at ``horizon``."""
        rng = random.Random(seed)
        events: List[FaultEvent] = []
        for kind, mtbf in (("node", node_mtbf), ("pilot", pilot_mtbf)):
            if not mtbf or mtbf <= 0.0:
                continue
            t = rng.expovariate(1.0 / mtbf)
            while t < horizon:
                events.append(FaultEvent(t, kind))
                t += rng.expovariate(1.0 / mtbf)
        return cls(events)


class ChaosController:
    """Drives a :class:`FaultPlan` against a ``CampaignScheduler``.

    Usage::

        sched = CampaignScheduler(...).add_pilot(p0, p1)
        chaos = ChaosController(sched, plan, seed=7)
        chaos.arm()            # schedules every event on the engine
        ...run the campaign...
        chaos.stats()          # {"node_failures": ..., "pilot_failures": ...}

    The controller is engine-agnostic: ``engine.schedule`` delivers the
    events as discrete simulation events or as real timer callbacks, and
    every injection commits under ``engine.lock``. Events that cannot fire
    safely (last surviving pilot, no node capacity left) are skipped and
    counted, never raised — chaos must not crash the run it is testing.
    """

    def __init__(self, scheduler, plan: FaultPlan, seed: int = 0):
        if scheduler.engine is None:
            raise RuntimeError("scheduler has no pilots; add_pilot first")
        self.sched = scheduler
        self.engine = scheduler.engine
        self.plan = plan
        self.rng = random.Random(seed)
        self.injected: List[Dict[str, Any]] = []
        self.skipped = 0
        self._armed = False

    # ---------------------------------------------------------------- arming
    def arm(self):
        """Schedule every planned event relative to now. Idempotent-ish:
        arming twice would double-inject, so it is refused."""
        if self._armed:
            raise RuntimeError("chaos plan already armed")
        self._armed = True
        for ev in self.plan:
            self.engine.schedule(max(ev.t, 1e-9), self._fire, ev)

    # --------------------------------------------------------------- firing
    def _fire(self, ev: FaultEvent):
        with self.engine.lock:
            if ev.kind == "pilot":
                self._fail_pilot(ev)
            else:
                self._fail_node(ev)

    def _live_views(self):
        return [v for v in self.sched.views if not v.dead]

    def _pick_view(self, ev: FaultEvent):
        live = self._live_views()
        if ev.pilot >= 0:
            v = self.sched.views[ev.pilot]
            return v if not v.dead else None
        return self.rng.choice(live) if live else None

    def _skip(self, ev: FaultEvent, why: str):
        self.skipped += 1
        self.engine.profiler.record(self.engine.now(), "chaos",
                                    TRACE_NAMES["skip"],
                                    {"kind": ev.kind, "why": why})

    def _fail_pilot(self, ev: FaultEvent):
        view = self._pick_view(ev)
        if view is None:
            return self._skip(ev, "no live pilot")
        if len(self._live_views()) < 2:
            return self._skip(ev, "last pilot")
        victims = self.sched.fail_pilot(view.index)
        self.injected.append({"t": self.engine.now(), "kind": "pilot",
                              "pilot": view.index,
                              "n_victims": len(victims)})

    def _fail_node(self, ev: FaultEvent):
        view = self._pick_view(ev)
        if view is None:
            return self._skip(ev, "no live pilot")
        ex, node = self._pick_node(view.agent, ev)
        if ex is None:
            return self._skip(ev, "no node capacity")
        victims = ex.fail_node(node, "node failure")
        if victims is None:
            return self._skip(ev, "node not owned")
        self.sched.on_node_failure(view.index, node)
        self.engine.profiler.record(
            self.engine.now(), "chaos", TRACE_NAMES["node_fail"],
            {"pilot": view.index, "backend": ex.name, "node": node,
             "n_victims": len(victims)})
        self.injected.append({"t": self.engine.now(), "kind": "node",
                              "pilot": view.index, "backend": ex.name,
                              "node": node, "n_victims": len(victims)})

    def _pick_node(self, agent, ev: FaultEvent):
        """Choose (executor, node id). Pool-backed backends are preferred
        (a real NodePool shrinks); pool-less real backends come last with a
        nominal node id — their ``fail_node`` emulates the loss. A pool
        must keep >= 1 node so the backend stays schedulable."""
        pooled, poolless = [], []
        for name, ex in agent.backends.items():
            if not ex.alive:
                continue
            if ev.backend and name != ev.backend:
                continue
            nodes = ex.live_nodes()
            if len(nodes) > 1:
                pooled.append((ex, nodes))
            elif not nodes and getattr(ex, "workers", 0) > 1:
                poolless.append(ex)
        if pooled:
            ex, nodes = self.rng.choice(pooled)
            node = ev.node if ev.node in nodes else self.rng.choice(nodes)
            return ex, node
        if poolless:
            return self.rng.choice(poolless), max(0, ev.node)
        return None, -1

    # ---------------------------------------------------------------- stats
    def stats(self) -> Dict[str, int]:
        # lazy import: observability consumes this package (alert rows in
        # RunReport), so the constant cannot be imported at module load
        from repro.observability.stream import ALERT_EVENT

        prof = self.engine.profiler
        return {
            "node_failures": sum(1 for i in self.injected
                                 if i["kind"] == "node"),
            "pilot_failures": sum(1 for i in self.injected
                                  if i["kind"] == "pilot"),
            "tasks_killed": sum(i["n_victims"] for i in self.injected),
            "skipped": self.skipped,
            # obs:alert rows any live Watcher recorded during the chaos
            # run — injected faults should surface as streamed alerts
            "alerts_observed": (len(prof.rows_np(ALERT_EVENT))
                                if prof.has_name(ALERT_EVENT) else 0),
        }
