"""Attention variants: GQA/MQA/MHA with RoPE flavors, and DeepSeek MLA.

Three entry modes, all pure functions:
  * full-sequence causal (train / prefill)
  * single-token decode against a KV cache
  * MLA decode uses the *absorbed-weight* formulation (scores computed in the
    512-dim latent space; only (c_kv, k_rope) are cached — the MLA memory win).
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import (apply_rope, init_linear, linear, init_rmsnorm,
                                 rmsnorm, rope_cos_sin, rot_dim_for)

NEG_INF = -2.0e38


# ============================================================ core (XLA path)
def attn_weights_core(q, k, *, scale: float, q_offset, kv_valid_len) -> jnp.ndarray:
    """Grouped-query causal attention scores+softmax.

    q: (B, Sq, KV, G, hd); k: (B, Sk, KV, hd). Returns weights (B,KV,G,Sq,Sk) f32.
    ``q_offset``: position of q[0] in the global sequence (scalar, traced ok).
    ``kv_valid_len``: number of valid cache entries (None -> all Sk valid).
    """
    B, Sq, KV, G, hd = q.shape
    Sk = k.shape[1]
    scores = jnp.einsum("bqkgd,bskd->bkgqs", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    q_pos = q_offset + jnp.arange(Sq)
    k_pos = jnp.arange(Sk)
    mask = k_pos[None, :] <= q_pos[:, None]                      # causal
    if kv_valid_len is not None:
        mask = mask & (k_pos[None, :] < kv_valid_len)
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    return jax.nn.softmax(scores, axis=-1)


def attn_core(q, k, v, *, scale: float, q_offset=0, kv_valid_len=None,
              use_pallas: bool = False) -> jnp.ndarray:
    """q (B,Sq,H,hd), k/v (B,Sk,KV,hd) -> (B,Sq,H,hd_v)."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    if use_pallas and Sq > 1 and kv_valid_len is None:
        from repro.kernels.flash_attention import ops as fa_ops
        return fa_ops.flash_attention(q, k, v, scale=scale, causal=True)
    if use_pallas and Sq == 1 and kv_valid_len is not None:
        from repro.kernels.decode_attention import ops as da_ops
        return da_ops.decode_attention(q, k, v, kv_valid_len, scale=scale)
    qg = q.reshape(B, Sq, KV, G, hd)
    w = attn_weights_core(qg, k, scale=scale, q_offset=q_offset,
                          kv_valid_len=kv_valid_len)
    o = jnp.einsum("bkgqs,bskd->bqkgd", w, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, v.shape[-1]).astype(q.dtype)


# ================================================================== GQA layer
def init_gqa(key, cfg: ModelConfig, dtype):
    H, KV, hd, d = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim, cfg.d_model
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": init_linear(kq, d, H * hd, dtype, bias=cfg.qkv_bias),
        "wk": init_linear(kk, d, KV * hd, dtype, bias=cfg.qkv_bias),
        "wv": init_linear(kv, d, KV * hd, dtype, bias=cfg.qkv_bias),
        "wo": init_linear(ko, H * hd, d, dtype,
                          stddev=1.0 / math.sqrt(H * hd * 2 * cfg.num_layers)),
    }


def gqa_rope(cfg: ModelConfig, q, k, positions):
    rd = rot_dim_for(cfg, cfg.head_dim)
    if rd == 0 or positions is None:
        return q, k
    cos, sin = rope_cos_sin(cfg, positions, rd)
    return apply_rope(q, cos, sin), apply_rope(k, cos, sin)


def gqa_full(p, x, cfg: ModelConfig, positions, *, return_kv: bool = False):
    """Full-sequence causal attention (train / prefill).

    Returns (out, (k, v) or None). positions: (B,S) or (3,B,S) for mrope.
    """
    B, S, _ = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = linear(p["wq"], x).reshape(B, S, H, hd)
    k = linear(p["wk"], x).reshape(B, S, KV, hd)
    v = linear(p["wv"], x).reshape(B, S, KV, hd)
    q, k = gqa_rope(cfg, q, k, positions)
    o = attn_core(q, k, v, scale=1.0 / math.sqrt(hd), use_pallas=cfg.use_pallas)
    out = linear(p["wo"], o.reshape(B, S, H * hd))
    return out, ((k, v) if return_kv else None)


def gqa_decode(p, x, cfg: ModelConfig, positions, k_cache, v_cache, index):
    """Single-token decode. x (B,1,d); caches (B,Smax,KV,hd); index = #tokens
    already cached. Returns (out, new_k_cache, new_v_cache)."""
    B = x.shape[0]
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = linear(p["wq"], x).reshape(B, 1, H, hd)
    k = linear(p["wk"], x).reshape(B, 1, KV, hd)
    v = linear(p["wv"], x).reshape(B, 1, KV, hd)
    q, k = gqa_rope(cfg, q, k, positions)
    k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k.astype(k_cache.dtype),
                                                  index, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v.astype(v_cache.dtype),
                                                  index, axis=1)
    o = attn_core(q, k_cache, v_cache, scale=1.0 / math.sqrt(hd),
                  q_offset=index, kv_valid_len=index + 1,
                  use_pallas=cfg.use_pallas)
    out = linear(p["wo"], o.reshape(B, 1, H * hd))
    return out, k_cache, v_cache


# ================================================================== MLA layer
def init_mla(key, cfg: ModelConfig, dtype):
    d, H = cfg.d_model, cfg.num_heads
    nope, rope_d, vdim, r = (cfg.qk_nope_head_dim, cfg.qk_rope_head_dim,
                             cfg.v_head_dim, cfg.kv_lora_rank)
    ks = jax.random.split(key, 6)
    return {
        "wq": init_linear(ks[0], d, H * (nope + rope_d), dtype),
        "w_dkv": init_linear(ks[1], d, r, dtype),
        "w_krope": init_linear(ks[2], d, rope_d, dtype),
        "kv_norm": init_rmsnorm(r, dtype),
        "w_uk": init_linear(ks[3], r, H * nope, dtype),
        "w_uv": init_linear(ks[4], r, H * vdim, dtype),
        "wo": init_linear(ks[5], H * vdim, d, dtype,
                          stddev=1.0 / math.sqrt(H * vdim * 2 * cfg.num_layers)),
    }


def _mla_dims(cfg):
    return (cfg.num_heads, cfg.qk_nope_head_dim, cfg.qk_rope_head_dim,
            cfg.v_head_dim, cfg.kv_lora_rank)


def mla_latents(p, x, cfg: ModelConfig, positions):
    """Compute (c_kv, k_rope) — the quantities MLA caches."""
    B, S, _ = x.shape
    H, nope, rope_d, vdim, r = _mla_dims(cfg)
    c_kv = rmsnorm(p["kv_norm"], linear(p["w_dkv"], x), cfg.norm_eps)   # (B,S,r)
    k_rope = linear(p["w_krope"], x).reshape(B, S, 1, rope_d)
    cos, sin = rope_cos_sin(cfg, positions, rope_d)
    k_rope = apply_rope(k_rope, cos, sin)
    return c_kv, k_rope, (cos, sin)


def mla_full(p, x, cfg: ModelConfig, positions, *, return_kv: bool = False):
    """Full-sequence MLA (train / prefill). Decompresses K/V explicitly."""
    B, S, _ = x.shape
    H, nope, rope_d, vdim, r = _mla_dims(cfg)
    c_kv, k_rope, (cos, sin) = mla_latents(p, x, cfg, positions)
    q = linear(p["wq"], x).reshape(B, S, H, nope + rope_d)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, cos, sin)
    k_nope = linear(p["w_uk"], c_kv).reshape(B, S, H, nope)
    v = linear(p["w_uv"], c_kv).reshape(B, S, H, vdim)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (B, S, H, rope_d))], -1)
    qf = jnp.concatenate([q_nope, q_rope], -1)
    o = attn_core(qf, k, v, scale=1.0 / math.sqrt(nope + rope_d),
                  use_pallas=cfg.use_pallas)
    out = linear(p["wo"], o.reshape(B, S, H * vdim))
    return out, ((c_kv, k_rope[:, :, 0, :]) if return_kv else None)


def mla_decode(p, x, cfg: ModelConfig, positions, ckv_cache, krope_cache, index):
    """Absorbed-weight MLA decode.

    scores[h, s] = q_nope[h] @ W_uk[h]^T @ c_kv[s]  +  q_rope[h] @ k_rope[s]
    out[h]       = (sum_s w[h,s] c_kv[s]) @ W_uv[h]
    Caches: ckv_cache (B,Smax,r), krope_cache (B,Smax,rope_d).
    """
    B = x.shape[0]
    H, nope, rope_d, vdim, r = _mla_dims(cfg)
    c_kv, k_rope, (cos, sin) = mla_latents(p, x, cfg, positions)
    ckv_cache = jax.lax.dynamic_update_slice_in_dim(
        ckv_cache, c_kv.astype(ckv_cache.dtype), index, axis=1)
    krope_cache = jax.lax.dynamic_update_slice_in_dim(
        krope_cache, k_rope[:, :, 0, :].astype(krope_cache.dtype), index, axis=1)

    q = linear(p["wq"], x).reshape(B, 1, H, nope + rope_d)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, cos, sin)
    w_uk = p["w_uk"]["w"].reshape(r, H, nope)
    # absorb: q_lat (B,1,H,r)
    q_lat = jnp.einsum("bqhn,rhn->bqhr", q_nope.astype(jnp.float32),
                       w_uk.astype(jnp.float32))
    scale = 1.0 / math.sqrt(nope + rope_d)
    s_lat = jnp.einsum("bqhr,bsr->bhqs", q_lat,
                       ckv_cache.astype(jnp.float32))
    s_rope = jnp.einsum("bqhd,bsd->bhqs", q_rope.astype(jnp.float32),
                        krope_cache.astype(jnp.float32))
    scores = (s_lat + s_rope) * scale
    Sk = ckv_cache.shape[1]
    mask = jnp.arange(Sk)[None, None, None, :] < (index + 1)
    scores = jnp.where(mask, scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    ctx_lat = jnp.einsum("bhqs,bsr->bqhr", w, ckv_cache.astype(jnp.float32))
    w_uv = p["w_uv"]["w"].reshape(r, H, vdim)
    o = jnp.einsum("bqhr,rhv->bqhv", ctx_lat, w_uv.astype(jnp.float32))
    out = linear(p["wo"], o.reshape(B, 1, H * vdim).astype(x.dtype))
    return out, ckv_cache, krope_cache
