"""The unified decoder-LM trunk covering all 10 assigned architectures.

One functional model, four families of layer stack:
  * dense / audio / vlm : [norm→attn, norm→mlp] × L, scanned
  * moe                 : optional leading dense layers + [norm→attn, norm→moe] × L
  * ssm                 : [norm→mamba2] × L, scanned
  * hybrid (zamba2)     : groups of ``attn_every`` mamba2 layers, each followed by
                          ONE weight-shared attention+MLP block; scanned over groups

Layers are stacked (leading L dim) and executed with ``lax.scan`` so the HLO
stays small at 512-device SPMD compiles; ``cfg.remat`` selects the activation
checkpoint policy applied to the scanned body.

Modes: ``forward(..., mode='train')`` full logits; ``mode='prefill'`` last-token
logits + filled caches; ``decode(...)`` single-token step against caches.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import layers as L
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib

Params = Dict[str, Any]


# ===================================================================== helpers
def _remat(cfg: ModelConfig, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.dots_saveable)
    return jax.checkpoint(fn)


def _scan(cfg: ModelConfig, body, carry, xs):
    """lax.scan over stacked layers, or an unrolled python loop when
    ``cfg.scan_layers=False`` (the dry-run uses unrolled HLO so that
    cost_analysis sees true trip counts; see launch/dryrun.py)."""
    if cfg.scan_layers:
        return jax.lax.scan(body, carry, xs)
    n = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(n):
        xi = jax.tree.map(lambda t: t[i], xs)
        carry, y = body(carry, xi)
        ys.append(y)
    if ys and jax.tree.leaves(ys[0]):
        ys = jax.tree.map(lambda *ts: jnp.stack(ts), *ys)
    else:
        ys = ys[0] if ys else None
    return carry, ys


def _split_stack(key, n: int):
    return jax.random.split(key, n)


def _hybrid_layout(cfg: ModelConfig) -> Tuple[int, int]:
    """(#full groups of ``attn_every`` ssm layers, #tail ssm layers)."""
    g = cfg.num_layers // cfg.attn_every
    return g, cfg.num_layers - g * cfg.attn_every


# ================================================================ block: dense
def init_dense_block(key, cfg: ModelConfig, dtype, *, use_moe: bool, d_ff: int):
    k1, k2 = jax.random.split(key)
    p = {"norm1": L.init_rmsnorm(cfg.d_model, dtype),
         "norm2": L.init_rmsnorm(cfg.d_model, dtype)}
    p["attn"] = (attn.init_mla(k1, cfg, dtype) if cfg.use_mla
                 else attn.init_gqa(k1, cfg, dtype))
    if use_moe:
        p["moe"] = moe_lib.init_moe(k2, cfg, dtype)
    else:
        import dataclasses
        mcfg = cfg if d_ff == cfg.d_ff else dataclasses.replace(cfg, d_ff=d_ff)
        p["mlp"] = L.init_mlp(k2, mcfg, d_ff, dtype)
    return p


def dense_block_full(p, x, cfg: ModelConfig, positions, *, use_moe: bool,
                     return_kv: bool):
    h = L.rmsnorm(p["norm1"], x, cfg.norm_eps)
    if cfg.use_mla:
        h, kv = attn.mla_full(p["attn"], h, cfg, positions, return_kv=return_kv)
    else:
        h, kv = attn.gqa_full(p["attn"], h, cfg, positions, return_kv=return_kv)
    x = x + h
    h = L.rmsnorm(p["norm2"], x, cfg.norm_eps)
    if use_moe:
        h, aux = moe_lib.moe_apply(p["moe"], h, cfg)
    else:
        h, aux = L.mlp(p["mlp"], h, cfg), jnp.zeros((), jnp.float32)
    return x + h, kv, aux


def dense_block_decode(p, x, cfg: ModelConfig, positions, cache, index, *,
                       use_moe: bool):
    h = L.rmsnorm(p["norm1"], x, cfg.norm_eps)
    if cfg.use_mla:
        h, c1, c2 = attn.mla_decode(p["attn"], h, cfg, positions,
                                    cache["c_kv"], cache["k_rope"], index)
        new_cache = {"c_kv": c1, "k_rope": c2}
    else:
        h, ck, cv = attn.gqa_decode(p["attn"], h, cfg, positions,
                                    cache["k"], cache["v"], index)
        new_cache = {"k": ck, "v": cv}
    x = x + h
    h = L.rmsnorm(p["norm2"], x, cfg.norm_eps)
    if use_moe:
        h, _ = moe_lib.moe_apply(p["moe"], h, cfg)
    else:
        h = L.mlp(p["mlp"], h, cfg)
    return x + h, new_cache


# ================================================================== block: ssm
def init_ssm_block(key, cfg: ModelConfig, dtype):
    return {"norm1": L.init_rmsnorm(cfg.d_model, dtype),
            "ssm": ssm_lib.init_mamba2(key, cfg, dtype)}


def ssm_block_full(p, x, cfg: ModelConfig, *, return_cache: bool):
    h = L.rmsnorm(p["norm1"], x, cfg.norm_eps)
    h, cache = ssm_lib.mamba2_full(p["ssm"], h, cfg, return_cache=return_cache)
    return x + h, cache


def ssm_block_decode(p, x, cfg: ModelConfig, cache):
    h = L.rmsnorm(p["norm1"], x, cfg.norm_eps)
    h, new_cache = ssm_lib.mamba2_decode(p["ssm"], h, cfg, cache)
    return x + h, new_cache


# ====================================================================== params
def init_params(key, cfg: ModelConfig) -> Params:
    dtype = jnp.dtype(cfg.dtype)
    k_embed, k_layers, k_extra, k_head = jax.random.split(key, 4)
    params: Params = {
        "embed": L.init_embedding(k_embed, cfg.padded_vocab, cfg.d_model, dtype),
        "final_norm": L.init_rmsnorm(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = L.init_linear(k_head, cfg.d_model, cfg.padded_vocab,
                                          dtype)
    fam = cfg.family
    if fam == "ssm":
        params["layers"] = jax.vmap(
            lambda k: init_ssm_block(k, cfg, dtype))(
                _split_stack(k_layers, cfg.num_layers))
    elif fam == "hybrid":
        n_groups, tail = _hybrid_layout(cfg)
        keys = _split_stack(k_layers, n_groups * cfg.attn_every).reshape(
            n_groups, cfg.attn_every, 2)
        params["ssm_groups"] = jax.vmap(jax.vmap(
            lambda k: init_ssm_block(k, cfg, dtype)))(keys)
        if tail:
            params["ssm_tail"] = jax.vmap(
                lambda k: init_ssm_block(k, cfg, dtype))(
                    _split_stack(jax.random.fold_in(k_layers, 1), tail))
        params["shared_attn"] = init_dense_block(
            k_extra, cfg, dtype, use_moe=False, d_ff=cfg.d_ff)
    elif fam == "moe":
        fd = cfg.first_dense_layers
        if fd:
            params["dense_layers"] = jax.vmap(
                lambda k: init_dense_block(k, cfg, dtype, use_moe=False,
                                           d_ff=cfg.d_ff))(
                    _split_stack(k_extra, fd))
        params["layers"] = jax.vmap(
            lambda k: init_dense_block(k, cfg, dtype, use_moe=True,
                                       d_ff=cfg.d_ff))(
                _split_stack(k_layers, cfg.num_layers - fd))
    else:
        params["layers"] = jax.vmap(
            lambda k: init_dense_block(k, cfg, dtype, use_moe=False,
                                       d_ff=cfg.d_ff))(
                _split_stack(k_layers, cfg.num_layers))
    return params


# ======================================================================= cache
def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Dict[str, Any]:
    """Preallocated decoding caches (stacked over layers), plus ``index``."""
    dtype = jnp.dtype(cfg.dtype)
    fam = cfg.family

    def gqa_cache(n_layers):
        if cfg.use_mla:
            return {"c_kv": jnp.zeros((n_layers, batch, max_len,
                                       cfg.kv_lora_rank), dtype),
                    "k_rope": jnp.zeros((n_layers, batch, max_len,
                                         cfg.qk_rope_head_dim), dtype)}
        return {"k": jnp.zeros((n_layers, batch, max_len, cfg.num_kv_heads,
                                cfg.head_dim), dtype),
                "v": jnp.zeros((n_layers, batch, max_len, cfg.num_kv_heads,
                                cfg.head_dim), dtype)}

    def ssm_cache(n_layers):
        K, di, G, N = cfg.ssm_conv, cfg.ssm_d_inner, cfg.ssm_groups, cfg.ssm_state
        H, P = cfg.ssm_heads, cfg.ssm_head_dim
        return {"conv_x": jnp.zeros((n_layers, batch, K - 1, di), dtype),
                "conv_B": jnp.zeros((n_layers, batch, K - 1, G * N), dtype),
                "conv_C": jnp.zeros((n_layers, batch, K - 1, G * N), dtype),
                "state": jnp.zeros((n_layers, batch, H, P, N), jnp.float32)}

    cache: Dict[str, Any] = {"index": jnp.zeros((), jnp.int32)}
    if fam == "ssm":
        cache["layers"] = ssm_cache(cfg.num_layers)
    elif fam == "hybrid":
        n_groups, tail = _hybrid_layout(cfg)
        cache["ssm_groups"] = jax.tree.map(
            lambda t: t.reshape((n_groups, cfg.attn_every) + t.shape[1:]),
            ssm_cache(n_groups * cfg.attn_every))
        if tail:
            cache["ssm_tail"] = ssm_cache(tail)
        cache["attn"] = gqa_cache(n_groups)
    elif fam == "moe" and cfg.first_dense_layers:
        cache["dense_layers"] = gqa_cache(cfg.first_dense_layers)
        cache["layers"] = gqa_cache(cfg.num_layers - cfg.first_dense_layers)
    else:
        cache["layers"] = gqa_cache(cfg.num_layers)
    return cache


# ===================================================================== forward
def _inputs_to_h(params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray]):
    if cfg.input_mode == "embeddings" and "embeds" in batch:
        x = batch["embeds"].astype(jnp.dtype(cfg.dtype))
    else:
        x = L.embed(params["embed"], batch["tokens"], cfg)
    if cfg.pos_embed == "sinusoidal":
        pos = batch["positions"]
        x = x + L.sinusoidal_pos_embed(pos, cfg.d_model, x.dtype)
    return x


def _logits(params, cfg: ModelConfig, x):
    if cfg.tie_embeddings:
        return L.unembed(params["embed"], x, cfg)
    return L.unembed(params["unembed"], x, cfg)


def forward(params: Params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray],
            *, mode: str = "train"
            ) -> Tuple[jnp.ndarray, jnp.ndarray, Optional[Dict[str, Any]]]:
    """Full-sequence forward.

    mode='train':   returns (logits (B,S,V), aux_loss, None)
    mode='prefill': returns (last-token logits (B,1,V), aux_loss, cache)
    """
    assert mode in ("train", "prefill")
    prefill = mode == "prefill"
    positions = batch["positions"]
    x = _inputs_to_h(params, cfg, batch)
    aux_total = jnp.zeros((), jnp.float32)
    caches: Dict[str, Any] = {}
    fam = cfg.family

    if fam == "ssm":
        def body(carry, lp):
            y, cache = ssm_block_full(lp, carry, cfg, return_cache=prefill)
            return y, cache
        x, cache = _scan(cfg, _remat(cfg, body), x, params["layers"])
        if prefill:
            caches["layers"] = cache

    elif fam == "hybrid":
        n_groups, tail = _hybrid_layout(cfg)
        shared = params["shared_attn"]

        def grp_body(carry, grp_params):
            y = carry
            def inner(c, lp):
                out, cache = ssm_block_full(lp, c, cfg, return_cache=prefill)
                return out, cache
            y, ssm_c = _scan(cfg, _remat(cfg, inner), y, grp_params)
            y, kv, _ = dense_block_full(shared, y, cfg, positions,
                                        use_moe=False, return_kv=prefill)
            return y, (ssm_c, kv)
        x, (ssm_caches, kvs) = _scan(cfg, grp_body, x, params["ssm_groups"])
        if tail:
            def t_body(c, lp):
                out, cache = ssm_block_full(lp, c, cfg, return_cache=prefill)
                return out, cache
            x, tail_c = _scan(cfg, _remat(cfg, t_body), x, params["ssm_tail"])
        if prefill:
            caches["ssm_groups"] = ssm_caches
            if tail:
                caches["ssm_tail"] = tail_c
            caches["attn"] = {"k": kvs[0], "v": kvs[1]}

    else:                                   # dense / moe / audio / vlm
        fd = cfg.first_dense_layers if fam == "moe" else 0
        if fd:
            def d_body(carry, lp):
                y, kv, _ = dense_block_full(lp, carry, cfg, positions,
                                            use_moe=False, return_kv=prefill)
                return y, kv
            x, kvs = _scan(cfg, _remat(cfg, d_body), x,
                                  params["dense_layers"])
            if prefill:
                caches["dense_layers"] = _kv_dict(cfg, kvs)

        use_moe = fam == "moe"
        def body(carry, lp):
            y, aux = carry
            y, kv, a = dense_block_full(lp, y, cfg, positions,
                                        use_moe=use_moe, return_kv=prefill)
            return (y, aux + a), kv
        (x, aux_total), kvs = _scan(
            cfg, _remat(cfg, body), (x, aux_total), params["layers"])
        if prefill:
            caches["layers"] = _kv_dict(cfg, kvs)

    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if prefill:
        x = x[:, -1:, :]
        caches["index"] = jnp.asarray(batch["positions"].shape[-1], jnp.int32)
    logits = _logits(params, cfg, x)
    return logits, aux_total, (caches if prefill else None)


def _kv_dict(cfg, kvs):
    if kvs is None:
        return None
    if cfg.use_mla:
        return {"c_kv": kvs[0], "k_rope": kvs[1]}
    return {"k": kvs[0], "v": kvs[1]}


# ====================================================================== decode
def decode(params: Params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray],
           cache: Dict[str, Any]
           ) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    """One decode step. batch: tokens (B,1) or embeds (B,1,d) + positions.

    Returns (logits (B,1,V), new_cache)."""
    index = cache["index"]
    positions = batch["positions"]
    x = _inputs_to_h(params, cfg, batch)
    new_cache: Dict[str, Any] = {"index": index + 1}
    fam = cfg.family

    if fam == "ssm":
        def body(carry, xs):
            lp, lc = xs
            y, nc = ssm_block_decode(lp, carry, cfg, lc)
            return y, nc
        x, nc = _scan(cfg, body, x, (params["layers"], cache["layers"]))
        new_cache["layers"] = nc

    elif fam == "hybrid":
        n_groups, tail = _hybrid_layout(cfg)
        shared = params["shared_attn"]

        def grp_body(carry, xs):
            grp_params, grp_ssm_cache, attn_c = xs
            y = carry
            def inner(c, xs2):
                lp, lc = xs2
                out, ncache = ssm_block_decode(lp, c, cfg, lc)
                return out, ncache
            y, ssm_nc = _scan(cfg, inner, y, (grp_params, grp_ssm_cache))
            y, attn_nc = dense_block_decode(shared, y, cfg, positions, attn_c,
                                            index, use_moe=False)
            return y, (ssm_nc, attn_nc)
        x, (ssm_nc, attn_nc) = _scan(
            cfg, grp_body, x,
            (params["ssm_groups"], cache["ssm_groups"], cache["attn"]))
        new_cache["ssm_groups"] = ssm_nc
        new_cache["attn"] = attn_nc
        if tail:
            def t_body(c, xs2):
                lp, lc = xs2
                out, ncache = ssm_block_decode(lp, c, cfg, lc)
                return out, ncache
            x, tail_nc = _scan(cfg, t_body, x,
                                      (params["ssm_tail"], cache["ssm_tail"]))
            new_cache["ssm_tail"] = tail_nc

    else:
        fd = cfg.first_dense_layers if fam == "moe" else 0
        if fd:
            def d_body(carry, xs):
                lp, lc = xs
                y, nc = dense_block_decode(lp, carry, cfg, positions, lc, index,
                                           use_moe=False)
                return y, nc
            x, nc = _scan(cfg, d_body, x,
                                 (params["dense_layers"], cache["dense_layers"]))
            new_cache["dense_layers"] = nc
        use_moe = fam == "moe"
        def body(carry, xs):
            lp, lc = xs
            y, nc = dense_block_decode(lp, carry, cfg, positions, lc, index,
                                       use_moe=use_moe)
            return y, nc
        x, nc = _scan(cfg, body, x, (params["layers"], cache["layers"]))
        new_cache["layers"] = nc

    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = _logits(params, cfg, x)
    return logits, new_cache
