"""Mamba2 (SSD) block: projections, causal depthwise convs, SSD scan, gated
RMSNorm, output projection. Full-sequence (train/prefill) and single-step
(decode) paths share parameters.

Deviation from the reference fused implementation (documented in DESIGN.md):
z/x/B/C/dt use separate projection matrices and x/B/C separate depthwise convs
— mathematically identical to the fused in_proj/conv (depthwise convs are
per-channel), but each tensor gets a clean mesh sharding.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import init_linear, linear, init_rmsnorm, rmsnorm


def init_mamba2(key, cfg: ModelConfig, dtype):
    d, di = cfg.d_model, cfg.ssm_d_inner
    H, P, G, N, K = (cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_groups,
                     cfg.ssm_state, cfg.ssm_conv)
    ks = jax.random.split(key, 10)
    # dt bias: softplus^-1 of dt ~ Uniform[1e-3, 0.1]
    dt_init = jnp.exp(jax.random.uniform(ks[0], (H,),
                      minval=math.log(1e-3), maxval=math.log(0.1)))
    dt_bias = dt_init + jnp.log(-jnp.expm1(-dt_init))
    A_log = jnp.log(jax.random.uniform(ks[1], (H,), minval=1.0, maxval=16.0))
    std_conv = 1.0 / math.sqrt(K)
    return {
        "wz": init_linear(ks[2], d, di, dtype),
        "wx": init_linear(ks[3], d, di, dtype),
        "wB": init_linear(ks[4], d, G * N, dtype),
        "wC": init_linear(ks[5], d, G * N, dtype),
        "wdt": init_linear(ks[6], d, H, dtype),
        "conv_x": (std_conv * jax.random.normal(ks[7], (K, di))).astype(dtype),
        "conv_B": (std_conv * jax.random.normal(ks[8], (K, G * N))).astype(dtype),
        "conv_C": (std_conv * jax.random.normal(ks[9], (K, G * N))).astype(dtype),
        "A_log": A_log.astype(jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": dt_bias.astype(jnp.float32),
        "norm": init_rmsnorm(di, dtype),
        "w_out": init_linear(jax.random.fold_in(key, 99), di, d, dtype,
                             stddev=1.0 / math.sqrt(di * 2 * cfg.num_layers)),
    }


def causal_conv(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv. x (B, S, C), w (K, C) -> (B, S, C)."""
    K = w.shape[0]
    S = x.shape[1]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    y = jnp.zeros_like(x)
    for k in range(K):
        y = y + w[k][None, None, :] * jax.lax.dynamic_slice_in_dim(xp, k, S, axis=1)
    return y


def causal_conv_step(x_t: jnp.ndarray, w: jnp.ndarray, cache: jnp.ndarray
                     ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x_t (B, C), cache (B, K-1, C) of previous inputs -> (y_t, new_cache)."""
    K = w.shape[0]
    window = jnp.concatenate([cache, x_t[:, None, :]], axis=1)     # (B,K,C)
    y = jnp.einsum("bkc,kc->bc", window, w)
    return y, window[:, 1:, :]


def _ssd_dispatch(cfg: ModelConfig, x4, dt, A, B4, C4, h0=None):
    from repro.kernels.ssd import ops as ssd_ops
    return ssd_ops.ssd(x4, dt, A, B4, C4, chunk=cfg.ssm_chunk,
                       use_pallas=cfg.use_pallas, h0=h0,
                       precision=cfg.ssd_precision)


def mamba2_full(p, x, cfg: ModelConfig, *, return_cache: bool = False):
    """Full-sequence SSD block. x (B, S, d) -> (y, cache or None)."""
    B, S, _ = x.shape
    H, P, G, N, K = (cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_groups,
                     cfg.ssm_state, cfg.ssm_conv)
    di = cfg.ssm_d_inner
    z = linear(p["wz"], x)
    xin_raw = linear(p["wx"], x)
    B_raw = linear(p["wB"], x)
    C_raw = linear(p["wC"], x)
    dt_raw = linear(p["wdt"], x)

    xin = jax.nn.silu(causal_conv(xin_raw, p["conv_x"]))
    Bc = jax.nn.silu(causal_conv(B_raw, p["conv_B"]))
    Cc = jax.nn.silu(causal_conv(C_raw, p["conv_C"]))
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])

    x4 = xin.reshape(B, S, H, P)
    B4 = Bc.reshape(B, S, G, N)
    C4 = Cc.reshape(B, S, G, N)
    A = -jnp.exp(p["A_log"])

    y4, h_final = _ssd_dispatch(cfg, x4, dt, A, B4, C4)
    y4 = y4 + (p["D"][None, None, :, None] * x4.astype(jnp.float32)).astype(y4.dtype)

    y = y4.reshape(B, S, di)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = linear(p["w_out"], y)

    cache = None
    if return_cache:
        cache = {
            "conv_x": _tail(xin_raw, K - 1),
            "conv_B": _tail(B_raw, K - 1),
            "conv_C": _tail(C_raw, K - 1),
            "state": h_final.astype(jnp.float32),
        }
    return out, cache


def _tail(t: jnp.ndarray, n: int) -> jnp.ndarray:
    """Last n positions along axis 1, left-padded with zeros if S < n."""
    S = t.shape[1]
    if S >= n:
        return t[:, S - n:, :]
    return jnp.pad(t, ((0, 0), (n - S, 0), (0, 0)))


def mamba2_decode(p, x, cfg: ModelConfig, cache):
    """Single-token decode. x (B, 1, d), cache dict -> (y (B,1,d), new_cache)."""
    from repro.kernels.ssd.ref import ssd_step
    B = x.shape[0]
    H, P, G, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_groups, cfg.ssm_state
    di = cfg.ssm_d_inner
    xt = x[:, 0, :]
    z = linear(p["wz"], xt)
    xin_raw = linear(p["wx"], xt)
    B_raw = linear(p["wB"], xt)
    C_raw = linear(p["wC"], xt)
    dt_raw = linear(p["wdt"], xt)

    xin, conv_x = causal_conv_step(xin_raw, p["conv_x"], cache["conv_x"])
    Bc, conv_B = causal_conv_step(B_raw, p["conv_B"], cache["conv_B"])
    Cc, conv_C = causal_conv_step(C_raw, p["conv_C"], cache["conv_C"])
    xin, Bc, Cc = jax.nn.silu(xin), jax.nn.silu(Bc), jax.nn.silu(Cc)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])

    A = -jnp.exp(p["A_log"])
    y3, h = ssd_step(xin.reshape(B, H, P), dt, A,
                     Bc.reshape(B, G, N), Cc.reshape(B, G, N), cache["state"])
    y3 = y3 + (p["D"][None, :, None]
               * xin.reshape(B, H, P).astype(jnp.float32)).astype(y3.dtype)
    y = y3.reshape(B, di)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = linear(p["w_out"], y)[:, None, :]
    new_cache = {"conv_x": conv_x, "conv_B": conv_B, "conv_C": conv_C, "state": h}
    return out, new_cache
