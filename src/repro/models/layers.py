"""Shared layer primitives: norms, linears, embeddings, positional encodings.

Pure-functional: params are nested dicts of jnp arrays; every ``init_*`` returns a
pytree, every ``apply`` is a pure function of (params, inputs).
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def truncated_normal_init(key, shape, stddev, dtype):
    return (stddev * jax.random.truncated_normal(key, -2.0, 2.0, shape)).astype(dtype)


def init_linear(key, d_in: int, d_out: int, dtype, *, bias: bool = False,
                stddev: Optional[float] = None):
    stddev = stddev if stddev is not None else 1.0 / math.sqrt(d_in)
    p = {"w": truncated_normal_init(key, (d_in, d_out), stddev, dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def linear(p, x):
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


# --------------------------------------------------------------------------- norm
def init_rmsnorm(d: int, dtype):
    return {"scale": jnp.zeros((d,), dtype)}     # stored as (w - 1): apply uses 1+w


def rmsnorm(p, x, eps: float):
    """RMSNorm with (1 + w) parametrization (covers both llama & gemma styles:
    llama-style init w=1 is stored as scale=0)."""
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + p["scale"].astype(jnp.float32))).astype(dt)


# ---------------------------------------------------------------------- embedding
def init_embedding(key, vocab: int, d: int, dtype):
    # 1/sqrt(d): keeps tied-unembedding logits O(1); gemma's sqrt(d) input
    # scaling (below) restores unit-variance embeddings where the arch wants it
    return {"table": truncated_normal_init(key, (vocab, d),
                                           1.0 / math.sqrt(d), dtype)}


def embed(p, tokens, cfg: ModelConfig):
    x = jnp.take(p["table"], tokens, axis=0)
    if cfg.gemma_norm:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    return x


def unembed(p, x, cfg: ModelConfig):
    """Project to (padded) vocab logits. ``p`` is the embedding table when tied."""
    return x @ p["table"].T if "table" in p else x @ p["w"]


# --------------------------------------------------------------------------- RoPE
def _rope_angles(positions, inv_freq):
    """positions (..., S) int32 -> angles (..., S, dim/2) f32."""
    return positions.astype(jnp.float32)[..., None] * inv_freq


def rope_cos_sin(cfg: ModelConfig, positions: jnp.ndarray, rot_dim: int
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """cos/sin tables.

    positions: (B, S) for full/partial RoPE; (3, B, S) for M-RoPE (t, h, w
    streams, qwen2-vl style).
    Returns cos, sin of shape (B, S, rot_dim // 2), float32.
    """
    half = rot_dim // 2
    inv_freq = 1.0 / (cfg.rope_theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    if cfg.rope_kind == "mrope":
        assert positions.ndim == 3, "mrope needs (3, B, S) position streams"
        sections = cfg.mrope_sections
        assert sum(sections) == half, (sections, half)
        parts = []
        start = 0
        for stream, sec in enumerate(sections):
            ang = _rope_angles(positions[stream], inv_freq[start:start + sec])
            parts.append(ang)
            start += sec
        ang = jnp.concatenate(parts, axis=-1)            # (B, S, half)
    else:
        ang = _rope_angles(positions, inv_freq)          # (B, S, half)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """NeoX-style rotate-half on the leading ``2 * cos.shape[-1]`` channels of x.

    x: (B, S, H, hd); cos/sin: (B, S, half). Channels beyond rot_dim pass through
    (partial RoPE, chatglm/stablelm style).
    """
    half = cos.shape[-1]
    rot_dim = 2 * half
    x_rot, x_pass = x[..., :rot_dim], x[..., rot_dim:]
    x1, x2 = x_rot[..., :half], x_rot[..., half:]
    c = cos[:, :, None, :].astype(jnp.float32)
    s = sin[:, :, None, :].astype(jnp.float32)
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([x1f * c - x2f * s, x2f * c + x1f * s], axis=-1)
    out = out.astype(x.dtype)
    if x_pass.shape[-1]:
        out = jnp.concatenate([out, x_pass], axis=-1)
    return out


def rot_dim_for(cfg: ModelConfig, head_dim: int) -> int:
    if cfg.rope_kind == "none":
        return 0
    if cfg.rope_kind == "partial":
        rd = int(cfg.rotary_pct * head_dim)
        return rd - (rd % 2)
    return head_dim


# --------------------------------------------------------------- sinusoidal (musicgen)
def sinusoidal_pos_embed(positions: jnp.ndarray, d_model: int, dtype) -> jnp.ndarray:
    """positions (B, S) -> (B, S, d_model), classic transformer sin/cos."""
    half = d_model // 2
    freq = jnp.exp(-math.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freq
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


# --------------------------------------------------------------------------- MLP
def init_mlp(key, cfg: ModelConfig, d_ff: int, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"w_in": init_linear(k1, cfg.d_model, d_ff, dtype),
         "w_out": init_linear(k2, d_ff, cfg.d_model, dtype)}
    if cfg.gated_mlp:
        p["w_gate"] = init_linear(k3, cfg.d_model, d_ff, dtype)
    return p


def _act(name: str, x):
    if name == "silu":
        return jax.nn.silu(x)
    if name == "gelu":
        return jax.nn.gelu(x, approximate=True)
    raise ValueError(name)


def mlp(p, x, cfg: ModelConfig, d_ff_override: Optional[int] = None):
    h = linear(p["w_in"], x)
    if cfg.gated_mlp:
        h = _act(cfg.act, linear(p["w_gate"], x)) * h
    else:
        h = _act(cfg.act, h)
    return linear(p["w_out"], h)
