"""Mixture-of-Experts layer: top-k routing, capacity-based scatter/gather
dispatch (PAX/MaxText style — no O(T^2) one-hot dispatch matmuls), optional
shared experts (DeepSeek), load-balance aux loss.

Experts are stacked (E, ...) so they shard over the ``model`` mesh axis
(expert parallelism); tokens are grouped along the batch dim so routing stays
group-local and the expert GEMM resharding is the only cross-shard exchange
(the all-to-all of classic EP).
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import init_linear, linear, init_mlp, mlp, _act


def init_moe(key, cfg: ModelConfig, dtype):
    E, d, ff = cfg.num_experts, cfg.d_model, cfg.d_ff_expert
    ks = jax.random.split(key, 5)
    std_in = 1.0 / math.sqrt(d)
    std_out = 1.0 / math.sqrt(ff * 2 * cfg.num_layers)
    p = {
        "router": {"w": (std_in * jax.random.truncated_normal(
            ks[0], -2, 2, (d, E))).astype(jnp.float32)},
        "w_in": (std_in * jax.random.truncated_normal(
            ks[1], -2, 2, (E, d, ff))).astype(dtype),
        "w_gate": (std_in * jax.random.truncated_normal(
            ks[2], -2, 2, (E, d, ff))).astype(dtype),
        "w_out": (std_out * jax.random.truncated_normal(
            ks[3], -2, 2, (E, ff, d))).astype(dtype),
    }
    if cfg.num_shared_experts:
        import dataclasses
        shared_cfg = dataclasses.replace(cfg, gated_mlp=True)
        p["shared"] = init_mlp(ks[4], shared_cfg,
                               cfg.num_shared_experts * ff, dtype)
    return p


def moe_capacity(cfg: ModelConfig, tokens_per_group: int) -> int:
    c = int(math.ceil(tokens_per_group / cfg.num_experts
                      * cfg.capacity_factor * cfg.top_k))
    return max(cfg.top_k, min(c, tokens_per_group))


def _route(logits: jnp.ndarray, cfg: ModelConfig):
    """logits (G, Tg, E) f32 -> (probs, top_p (G,Tg,K), top_i (G,Tg,K))."""
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, cfg.top_k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)   # renormalize
    return probs, top_p, top_i


def moe_apply(p, x: jnp.ndarray, cfg: ModelConfig
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x (B, S, d) -> (y (B, S, d), aux_loss scalar).

    Token groups: one group per batch row when S > 1 (train/prefill), a single
    global group for decode (S == 1).
    """
    B, S, d = x.shape
    E, K = cfg.num_experts, cfg.top_k
    if S > 1:
        G, Tg = B, S
        xg = x
    else:
        G, Tg = 1, B
        xg = x.reshape(1, B, d)

    C = moe_capacity(cfg, Tg)
    logits = (xg.astype(jnp.float32) @ p["router"]["w"])        # (G,Tg,E)
    probs, top_p, top_i = _route(logits, cfg)

    # --- slot assignment: k-priority, token-order within expert -------------
    counts = jnp.zeros((G, E), jnp.int32)
    dests, keeps, gates = [], [], []
    for j in range(K):
        mask_j = jax.nn.one_hot(top_i[..., j], E, dtype=jnp.int32)   # (G,Tg,E)
        pos_j = jnp.cumsum(mask_j, axis=1) - 1 + counts[:, None, :]
        pos_in_e = jnp.sum(pos_j * mask_j, axis=-1)                  # (G,Tg)
        counts = counts + jnp.sum(mask_j, axis=1)
        keep = pos_in_e < C
        e_j = top_i[..., j]
        dest = jnp.where(keep, e_j * C + pos_in_e, E * C)            # dump slot
        dests.append(dest)
        keeps.append(keep)
        gates.append(top_p[..., j] * keep)

    # --- scatter tokens into expert buffers (G, E, C, d) ---------------------
    n_slots = (E + 1) * C                                            # +dump expert
    buf = jax.vmap(lambda xg_, *ds: _scatter(xg_, ds, n_slots))(xg, *dests)
    x_e = buf.reshape(G, E + 1, C, d)[:, :E]                         # (G,E,C,d)
    if cfg.moe_dispatch_constraint:
        # pin the expert-parallel layout: groups stay data-sharded, experts
        # model-sharded -> the reshard is a single all-to-all-shaped exchange
        from jax.sharding import PartitionSpec as _P
        try:
            x_e = jax.lax.with_sharding_constraint(
                x_e, _P("data" if G > 1 else None, "model", None, None))
        except ValueError:
            # under shard_map manual over the data axis (int8-compressed
            # grads path) only the model axis is Auto-visible
            x_e = jax.lax.with_sharding_constraint(
                x_e, _P(None, "model", None, None))

    # --- expert GEMMs ---------------------------------------------------------
    h = jnp.einsum("gecd,edf->gecf", x_e, p["w_in"])
    g = jnp.einsum("gecd,edf->gecf", x_e, p["w_gate"])
    h = _act(cfg.act, g) * h
    y_e = jnp.einsum("gecf,efd->gecd", h, p["w_out"])                # (G,E,C,d)

    # --- gather back ----------------------------------------------------------
    y_flat = jnp.concatenate(
        [y_e.reshape(G, E * C, d), jnp.zeros((G, C, d), y_e.dtype)], axis=1)
    out = jnp.zeros_like(xg)
    for j in range(K):
        picked = jnp.take_along_axis(y_flat, dests[j][..., None], axis=1)
        out = out + picked * gates[j][..., None].astype(picked.dtype)

    # --- shared experts --------------------------------------------------------
    if "shared" in p:
        out = out + mlp(p["shared"], xg, cfg)

    # --- load-balance aux loss (Switch-style) -----------------------------------
    frac = jnp.mean(jax.nn.one_hot(top_i[..., 0], E, dtype=jnp.float32), axis=(0, 1))
    mean_prob = jnp.mean(probs, axis=(0, 1))
    aux = cfg.router_aux_coef * E * jnp.sum(frac * mean_prob)

    return out.reshape(B, S, d), aux


def _scatter(x_g: jnp.ndarray, dests, n_slots: int) -> jnp.ndarray:
    buf = jnp.zeros((n_slots, x_g.shape[-1]), x_g.dtype)
    for dest in dests:
        buf = buf.at[dest].add(x_g)
    return buf
